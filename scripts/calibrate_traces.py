"""Trace-profile calibration (the methodology referenced in core/traces.py).

Searches hot_mass per workload so that Base-CSSD's DRAM-vs-CXL slowdown
lands near a target taken from the paper's Fig 2 range (1.5-31.4x). The
shipped WORKLOADS table was produced with this script plus the structural
choices documented in DESIGN.md §Layer A (3-tier read set, warm write
set, die-parallel flash model).

  PYTHONPATH=src python scripts/calibrate_traces.py
"""
import dataclasses

from repro.core import traces as T
from repro.core.simulator import simulate

TARGETS = {"bfs-dense": 31.0, "bc": 8.0, "radix": 5.0, "srad": 12.0,
           "ycsb": 10.0, "tpcc": 3.0, "dlrm": 20.0}


def calibrate(wl: str, target: float, total_req: int = 200_000, iters: int = 6):
    spec0 = T.WORKLOADS[wl]
    lo, hi = 0.75, 0.9995
    best = None
    for _ in range(iters):
        mid = (lo + hi) / 2
        T.WORKLOADS[wl] = dataclasses.replace(spec0, hot_mass=mid)
        b = simulate(wl, "base-cssd", total_req=total_req)
        d = simulate(wl, "dram-only", total_req=total_req)
        ratio = b["exec_ns"] / d["exec_ns"]
        best = (mid, ratio)
        if ratio > target:
            lo = mid
        else:
            hi = mid
    T.WORKLOADS[wl] = spec0
    return best


if __name__ == "__main__":
    for wl, tgt in TARGETS.items():
        mass, ratio = calibrate(wl, tgt)
        print(f"{wl:10s} target={tgt:5.1f} -> hot_mass={mass:.4f} ratio={ratio:6.1f}")
