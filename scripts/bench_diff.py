"""Diff a fresh BENCH_sim.json against the committed reference baseline.

Gates on **CPU time** (grid worker CPU + per-section render CPU): the
shared-core CI container's wall clock swings +-50% with steal, which made
the original >20% wall gate a latent flake. Wall clocks are still printed,
but as information only — they never fail the run.

Fails (exit 1) when:
  * a baseline section ran but errored in the fresh run, or
  * the grid's summed worker CPU regresses by more than --tolerance over
    the same number of freshly simulated cells, or
  * a section's render CPU regresses by more than --tolerance (only
    sections spending >= 1s of CPU are gated; faster renders measure
    interpreter noise, not code).

  PYTHONPATH=src python scripts/bench_diff.py \
      --baseline BENCH_baseline.json --fresh BENCH_sim.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _sections(report: dict, key: str) -> dict:
    return {name: sec.get(key, 0.0)
            for name, sec in report.get("sections", {}).items()
            if sec.get("status") == "ok"}


def compare(baseline: dict, fresh: dict, tolerance: float) -> tuple:
    """Returns (problems, infos): problems fail the gate, infos do not."""
    problems = []
    infos = []
    base_cpu = _sections(baseline, "cpu_s")
    fresh_cpu = _sections(fresh, "cpu_s")
    base_wall = _sections(baseline, "wall_s")
    fresh_wall = _sections(fresh, "wall_s")
    for name, bc in sorted(base_cpu.items()):
        if name not in fresh_cpu:
            # partial runs (ci.sh smokes a section subset) are fine; a
            # section that RAN but errored fails
            if name in fresh.get("sections", {}):
                problems.append(f"section {name}: status "
                                f"{fresh['sections'][name].get('status')!r}")
            continue
        fc = fresh_cpu[name]
        if bc >= 1.0 and fc > bc * (1.0 + tolerance):
            problems.append(f"section {name}: {fc:.2f}s cpu vs baseline "
                            f"{bc:.2f}s (+{(fc / bc - 1.0) * 100:.0f}%)")
        bw, fw = base_wall.get(name, 0.0), fresh_wall.get(name, 0.0)
        if bw >= 1.0 and fw > bw * (1.0 + tolerance):
            infos.append(f"section {name} wall: {fw:.2f}s vs {bw:.2f}s "
                         f"(informational; steal-noisy)")
    bg = baseline.get("grid", {}).get("cpu_s", 0.0)
    fg = fresh.get("grid", {}).get("cpu_s", 0.0)
    bn = baseline.get("grid", {}).get("cells_run", 0)
    fn = fresh.get("grid", {}).get("cells_run", 0)
    # grid cpu is only comparable when both runs simulated the same number
    # of fresh cells (a warm cache makes cpu_s ~0)
    if bn and fn == bn and bg >= 1.0 and fg > bg * (1.0 + tolerance):
        problems.append(f"grid cpu: {fg:.0f}s vs baseline {bg:.0f}s "
                        f"(+{(fg / bg - 1.0) * 100:.0f}%) over {fn} cells")
    bgw = baseline.get("grid", {}).get("wall_s", 0.0)
    fgw = fresh.get("grid", {}).get("wall_s", 0.0)
    if bn and fn == bn and bgw >= 1.0 and fgw > bgw * (1.0 + tolerance):
        infos.append(f"grid wall: {fgw:.0f}s vs {bgw:.0f}s (informational)")
    # latency-provenance summaries ride along with the calibration cells;
    # obs is an instrumentation layer, never a perf gate (its correctness
    # contract is enforced by tests/test_obs.py, not by this diff)
    for cell, c in sorted(fresh.get("engine_reqps", {}).items()):
        ob = c.get("obs")
        if ob:
            infos.append(
                f"obs {cell}: conservation "
                f"{'ok' if ob.get('conservation_pass') else 'FAIL'}, "
                f"{ob.get('n_miss', 0)} reads / {ob.get('n_stall', 0)} "
                f"stalls attributed, {ob.get('closure_fallbacks', 0)} "
                f"closure fallbacks (informational)")
    # turbo blocks are informational too: the two-tier contract is
    # enforced by tests/test_engine_turbo.py, and the perf acceptance by
    # the paired --engines protocol — not by this cold-vs-cold diff
    for cell, c in sorted(fresh.get("engine_reqps", {}).items()):
        tb = c.get("turbo")
        if tb:
            infos.append(
                f"turbo {cell}: {tb.get('events_per_sec', 0.0):.2e} ev/s, "
                f"{tb.get('speedup_vs_batched', 0.0):.2f}x vs batched, "
                f"drift_max {tb.get('drift_max', 0.0):.1e}"
                f"{', FELL BACK' if tb.get('fallback') else ''} "
                f"(informational)")
    pe = fresh.get("paired_engines")
    if pe:
        for cell, r in sorted(pe.get("cells", {}).items()):
            infos.append(
                f"paired {pe.get('baseline')}->{pe.get('candidate')} "
                f"{cell}: {r.get('speedup', 0.0):.2f}x (interleaved "
                f"best-of-3 CPU, informational)")
    return problems, infos


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_sim.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional CPU regression (default 0.20)")
    args = ap.parse_args(argv)
    bpath, fpath = Path(args.baseline), Path(args.fresh)
    if not bpath.exists():
        print(f"# bench_diff: no baseline at {bpath}; skipping "
              f"(commit one from a quiet run of this machine class)")
        return 0
    if not fpath.exists():
        print(f"bench_diff: fresh report {fpath} not found", file=sys.stderr)
        return 1
    baseline = json.loads(bpath.read_text())
    fresh = json.loads(fpath.read_text())
    if baseline.get("quick") != fresh.get("quick"):
        print("# bench_diff: baseline and fresh runs used different --quick "
              "settings; sections are not comparable, skipping")
        return 0
    if not any("cpu_s" in s for s in baseline.get("sections", {}).values()):
        print("# bench_diff: baseline predates per-section cpu_s; "
              "re-baseline from a run of this revision, skipping")
        return 0
    problems, infos = compare(baseline, fresh, args.tolerance)
    for note in infos:
        print(f"# bench_diff info: {note}")
    if problems:
        print(f"bench_diff: CPU regressions beyond {args.tolerance:.0%}:",
              file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"# bench_diff: {len(_sections(fresh, 'cpu_s'))} sections within "
          f"{args.tolerance:.0%} of baseline (CPU time)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
