"""Diff a fresh BENCH_sim.json against the committed reference baseline.

Fails (exit 1) when any section's wall clock regresses by more than
--tolerance (default 20%) relative to BENCH_baseline.json, or when a
baseline section is missing from the fresh run. Sections only present in
the fresh run are reported but never fail (new benchmarks are not
regressions).

Wall clocks on shared CI boxes are steal-noisy, so the check is applied to
per-section render wall AND to the grid's cpu seconds (the more stable
signal); --tolerance applies to both.

  PYTHONPATH=src python scripts/bench_diff.py \
      --baseline BENCH_baseline.json --fresh BENCH_sim.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _section_walls(report: dict) -> dict:
    return {name: sec.get("wall_s", 0.0)
            for name, sec in report.get("sections", {}).items()
            if sec.get("status") == "ok"}


def compare(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Returns a list of human-readable regression strings (empty = pass)."""
    problems = []
    base_w = _section_walls(baseline)
    fresh_w = _section_walls(fresh)
    for name, bw in sorted(base_w.items()):
        if name not in fresh_w:
            # partial runs (ci.sh smokes a section subset) are fine; a
            # section that RAN but errored is caught by _section_walls
            # requiring status == "ok" on the fresh side below
            if name in fresh.get("sections", {}):
                problems.append(f"section {name}: status "
                                f"{fresh['sections'][name].get('status')!r}")
            continue
        fw = fresh_w[name]
        # sub-second sections are render-only (warm cache); absolute jitter
        # there is scheduling noise, not regression
        if bw >= 1.0 and fw > bw * (1.0 + tolerance):
            problems.append(f"section {name}: {fw:.2f}s vs baseline "
                            f"{bw:.2f}s (+{(fw / bw - 1.0) * 100:.0f}%)")
    bg = baseline.get("grid", {}).get("cpu_s", 0.0)
    fg = fresh.get("grid", {}).get("cpu_s", 0.0)
    bn = baseline.get("grid", {}).get("cells_run", 0)
    fn = fresh.get("grid", {}).get("cells_run", 0)
    # grid cpu is only comparable when both runs simulated the same number
    # of fresh cells (a warm cache makes cpu_s ~0)
    if bn and fn == bn and bg >= 1.0 and fg > bg * (1.0 + tolerance):
        problems.append(f"grid cpu: {fg:.0f}s vs baseline {bg:.0f}s "
                        f"(+{(fg / bg - 1.0) * 100:.0f}%) over {fn} cells")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_sim.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args(argv)
    bpath, fpath = Path(args.baseline), Path(args.fresh)
    if not bpath.exists():
        print(f"# bench_diff: no baseline at {bpath}; skipping "
              f"(commit one from a quiet run of this machine class)")
        return 0
    if not fpath.exists():
        print(f"bench_diff: fresh report {fpath} not found", file=sys.stderr)
        return 1
    baseline = json.loads(bpath.read_text())
    fresh = json.loads(fpath.read_text())
    if baseline.get("quick") != fresh.get("quick"):
        print("# bench_diff: baseline and fresh runs used different --quick "
              "settings; sections are not comparable, skipping")
        return 0
    problems = compare(baseline, fresh, args.tolerance)
    if problems:
        print("bench_diff: wall-clock regressions beyond "
              f"{args.tolerance:.0%}:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"# bench_diff: {len(_section_walls(fresh))} sections within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
