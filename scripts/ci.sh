#!/usr/bin/env bash
# CI entry point: engine-parity smoke + tier-1 tests + a parallel smoke of
# the benchmark orchestrator diffed against the committed baseline.
# Mirrors what a GitHub Actions job would run; keep it fast (~10 min on
# 2 cores).
#
#   bash scripts/ci.sh            # everything
#   bash scripts/ci.sh parity     # engine-parity smoke only (~15 s)
#   bash scripts/ci.sh tests      # tier-1 pytest only
#   bash scripts/ci.sh bench      # orchestrator smoke + baseline diff
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
STAGE="${1:-all}"

if [[ "$STAGE" == "all" || "$STAGE" == "parity" ]]; then
  echo "== engine parity smoke (ctx-bound + stable-state, both engines) =="
  # Runs before everything else: if the batched engine's classification
  # cache breaks bit-compatibility, fail in seconds, not after the suite.
  python scripts/parity_smoke.py
fi

if [[ "$STAGE" == "all" || "$STAGE" == "tests" ]]; then
  echo "== tier-1: pytest =="
  # NOTE: hypothesis is an optional dev dependency; tests fall back to
  # tests/_hypothesis_compat.py when it is absent.
  python -m pytest -x -q
fi

if [[ "$STAGE" == "all" || "$STAGE" == "bench" ]]; then
  echo "== benchmark orchestrator smoke (--quick --jobs 2) =="
  # Two representative sections: fig14 covers the full 7x8 variant grid,
  # fig9 covers per-cfg cache keys. --profile prints grid req/s.
  python -m benchmarks.run --quick --jobs 2 --only fig14,fig9 \
    --skip-roofline --profile
  test -f BENCH_sim.json && echo "BENCH_sim.json written"
  echo "== wall-clock diff vs committed baseline (>20% regression fails) =="
  python scripts/bench_diff.py --baseline BENCH_baseline.json \
    --fresh BENCH_sim.json --tolerance 0.20
fi

echo "CI OK"
