#!/usr/bin/env bash
# CI entry point: engine-parity smoke + tier-1 tests + a reference-engine
# pass over the simulator test subset + a parallel smoke of the benchmark
# orchestrator diffed against the committed baseline.
# Mirrors what a GitHub Actions job would run; keep it fast (~10 min on
# 2 cores).
#
#   bash scripts/ci.sh            # everything
#   bash scripts/ci.sh parity     # engine-parity smoke only (~15 s)
#   bash scripts/ci.sh tests      # tier-1 pytest only
#   bash scripts/ci.sh ref        # simulator tests on the reference engine
#   bash scripts/ci.sh gc         # block-FTL GC/tail figure in quick mode
#   bash scripts/ci.sh addr       # physical-routing parity (engines x FTLs)
#   bash scripts/ci.sh fused      # fused-boundary-engine conflict parity
#   bash scripts/ci.sh faults     # fault model + crash-recovery suite
#   bash scripts/ci.sh qos        # die-level QoS: suspend/priority/striping
#   bash scripts/ci.sh obs        # latency provenance: conservation + export
#   bash scripts/ci.sh bench      # orchestrator smoke + baseline diff
#   bash scripts/ci.sh turbo      # fast-math turbo engine: two-tier contract
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
STAGE="${1:-all}"

if [[ "$STAGE" == "all" || "$STAGE" == "parity" ]]; then
  echo "== engine parity smoke (ctx-bound + stable-state, both engines) =="
  # Runs before everything else: if the batched engine breaks
  # bit-compatibility against the shared DeviceState, fail in seconds,
  # not after the suite.
  python scripts/parity_smoke.py
fi

if [[ "$STAGE" == "all" || "$STAGE" == "tests" ]]; then
  echo "== tier-1: pytest =="
  # NOTE: hypothesis is an optional dev dependency; tests fall back to
  # tests/_hypothesis_compat.py when it is absent.
  python -m pytest -x -q
fi

if [[ "$STAGE" == "all" || "$STAGE" == "ref" ]]; then
  echo "== simulator subset on the REFERENCE engine =="
  # Both engines mutate one DeviceState; pairwise parity alone would miss
  # a bug that breaks both identically. Forcing the reference engine over
  # the behavioural simulator tests catches reference-side drift against
  # the shared state directly.
  # REPRO_SIM_ENGINE_PIN=1 tells tests/conftest.py the override is
  # deliberate (it otherwise strips REPRO_SIM_ENGINE so leaked env can't
  # turn parity suites into self-comparisons)
  REPRO_SIM_ENGINE=reference REPRO_SIM_ENGINE_PIN=1 \
    python -m pytest -x -q tests/test_simulator.py
fi

if [[ "$STAGE" == "all" || "$STAGE" == "gc" ]]; then
  echo "== block-FTL GC / tail-latency figure (quick) =="
  # Exercises the block-granular flash backend end-to-end (OP x victim-
  # policy sweep, WAF + p99 rows) without touching BENCH_sim.json; the
  # bench stage below carries the same section through the CPU-time gate.
  python - <<'PY'
from benchmarks import fig_gc_tail
rows = fig_gc_tail.main(total_req=200_000)
assert rows, "fig_gc_tail produced no rows"
assert any(r["gc_events"] > 0 for r in rows), "GC never engaged in sweep"
PY
fi

if [[ "$STAGE" == "all" || "$STAGE" == "addr" ]]; then
  echo "== physical-address routing parity (both engines, both FTL backends) =="
  # The l2p-routed service path: resolver/legacy-hash anchors, routing
  # divergence after GC relocation, placement-policy (wear_leveling x
  # hotcold) storm parity, and the l2p agreement property sweep. The
  # routing tests drive BOTH engines explicitly per test; the legacy
  # tests pin the ftl_backend="legacy" anchor.
  python -m pytest -x -q tests/test_flash.py -k "routing or legacy"
fi

if [[ "$STAGE" == "all" || "$STAGE" == "fused" ]]; then
  echo "== fused boundary engine: conflict-fallback + window parity =="
  # The fused scheduler's windows must stay bit-exact under same-set /
  # same-l2p collision pressure, with prediction on and off. Bench gate
  # note: the paired-speedup acceptance for the fused engine is measured
  # with scripts/paired_bench.py --cells bfs-dense against the previous
  # PR's HEAD (interleaved best-of-3 CPU); the bench stage below only
  # gates against BENCH_baseline.json, which was re-based cold after the
  # fused engine landed.
  python -m pytest -x -q tests/test_engine_fused.py tests/test_simulator.py \
    -k "fused or window or trace_cache"
fi

if [[ "$STAGE" == "all" || "$STAGE" == "faults" ]]; then
  echo "== device fault model: parity under faults + crash recovery =="
  # Every fault class (retry ladder, outages, power loss, die failure)
  # firing with both engines bit-exact, replay idempotence after double
  # crashes, and spare-exhaustion degrading read-only instead of raising.
  python -m pytest -x -q tests/test_faults.py
fi

if [[ "$STAGE" == "all" || "$STAGE" == "qos" ]]; then
  echo "== die-level QoS: GC suspend/resume + read priority + superblock =="
  # The QoS knob grid bit-exact across both engines, suspend budgets
  # bounded per carved window, read-p99 monotone under read priority,
  # and striped-frontier placement agreeing with the blk_loc contract.
  python -m pytest -x -q tests/test_qos.py -k "qos or suspend or superblock"
fi

if [[ "$STAGE" == "all" || "$STAGE" == "obs" ]]; then
  echo "== latency provenance: conservation + parity + trace export =="
  # Every scenario's components must sum bit-exactly to the recorded
  # latencies on both engines, zero-obs configs must attach nothing
  # (fused engine stays eligible), and the Perfetto export must be
  # valid, deterministic trace-event JSON.
  python -m pytest -x -q tests/test_obs.py
fi

if [[ "$STAGE" == "all" || "$STAGE" == "bench" ]]; then
  echo "== benchmark orchestrator smoke (--quick, auto physical-core jobs) =="
  # Representative sections: fig14 covers the full 7x8 variant grid, fig9
  # covers per-cfg cache keys, gc_tail covers the block-FTL sweep (so the
  # CPU-time gate below sees the flash backend), faults covers the fault
  # model's scheduler-path cells, breakdown covers the obs-enabled grid
  # (component stacks + conservation column). --profile prints req/s.
  python -m benchmarks.run --quick --only fig14,fig9,gc_tail,faults,breakdown \
    --skip-roofline --profile
  test -f BENCH_sim.json && echo "BENCH_sim.json written"
  echo "== CPU-time diff vs committed baseline (wall is informational) =="
  # CPU time is the gated signal: wall swings +-50% with steal on this
  # container class. CPU itself still inflates up to ~40% when a noisy
  # neighbour sits on the SMT sibling (process_time counts scheduled
  # seconds, and IPC drops), so the gate gets 35% headroom — real engine
  # regressions we care about are larger, and the old 20% *wall* gate
  # was a latent flake.
  python scripts/bench_diff.py --baseline BENCH_baseline.json \
    --fresh BENCH_sim.json --tolerance 0.35
fi

if [[ "$STAGE" == "all" || "$STAGE" == "turbo" ]]; then
  echo "== fast-math turbo engine: two-tier contract + dispatch microbench =="
  # The turbo engine reassociates float additions, so its contract is
  # split: discrete outputs (scheduler decisions, counts, FTL state,
  # DeviceState.discrete_signature()) bit-equal to the reference; timing
  # outputs within turbo_rtol with an exported a-priori drift bound;
  # conflict classes (faults/QoS/obs/inline-promo) refusing to the
  # bit-exact fallback. Perf acceptance is measured separately with
  # scripts/paired_bench.py --engines batched,turbo (interleaved
  # best-of-3 CPU); this stage gates correctness, not speed.
  python -m pytest -x -q tests/test_engine_turbo.py
  # Record the dispatch-fee numbers that motivate the design. Runs after
  # the bench stage so the merge into BENCH_sim.json persists.
  if [[ -f BENCH_sim.json ]]; then
    python scripts/dispatch_overhead.py --json BENCH_sim.json
  else
    python scripts/dispatch_overhead.py
  fi
fi

echo "CI OK"
