"""Engine-parity smoke: both replay engines on two sentinel cells.

Runs one ctx-switch-bound cell (bfs-dense/skybyte-c: short quanta, the
classification cache's repair machinery under maximum churn) and one
stable-state cell (srad/skybyte-w: long vector runs, compaction
boundaries) with both engines and asserts every stat matches — integers
exactly, floats to 1e-12 relative. Catches parity breakage in seconds,
before the full suite or benchmark grid runs.

  PYTHONPATH=src python scripts/parity_smoke.py [total_req]
"""
from __future__ import annotations

import dataclasses
import os
import sys

from repro.configs.base import SimConfig
from repro.core.simulator import simulate

CELLS = (("bfs-dense", "skybyte-c"), ("srad", "skybyte-w"))

# A lingering REPRO_SIM_ENGINE override (e.g. exported by a benchmarks.run
# --engine session) would force BOTH runs onto one engine and turn this
# gate into a self-comparison; parity must always pit the real pair.
os.environ.pop("REPRO_SIM_ENGINE", None)


def assert_same(a: dict, b: dict, cell: str) -> None:
    assert set(a) == set(b), (cell, set(a) ^ set(b))
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, float) or isinstance(y, float):
            ref = max(abs(float(x)), abs(float(y)), 1e-9)
            assert abs(float(x) - float(y)) <= 1e-12 * ref + 1e-9, \
                f"{cell}: {k} diverged ({x} vs {y})"
        else:
            assert x == y, f"{cell}: {k} diverged ({x} vs {y})"


def main(total_req: int = 60_000) -> None:
    for workload, variant in CELLS:
        results = {}
        for engine in ("reference", "batched"):
            cfg = dataclasses.replace(SimConfig(), engine=engine)
            results[engine] = simulate(workload, variant, cfg,
                                       total_req=total_req, seed=0)
        assert_same(results["reference"], results["batched"],
                    f"{workload}/{variant}")
        print(f"# parity ok: {workload}/{variant} "
              f"({results['batched']['n']} req, both engines bit-equal)")
    print("ENGINE PARITY OK")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 60_000)
