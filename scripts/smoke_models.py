"""Dev smoke: every reduced arch — forward, loss+grad, prefill, decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced
from repro.models.api import ModelSpec

ok = True
for arch in ARCH_IDS:
    cfg = get_reduced(arch)
    spec = ModelSpec(cfg)
    rng = jax.random.PRNGKey(0)
    try:
        params = spec.init(rng)
        batch = spec.smoke_batch(rng, batch=2, seq=32)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: spec.loss(p, batch), has_aux=True
        )(params)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
        )
        assert jnp.isfinite(loss), f"{arch}: loss not finite: {loss}"
        assert jnp.isfinite(gnorm), f"{arch}: grad norm not finite"
        # prefill + decode
        logits, cache = spec.prefill(params, batch["tokens"], batch.get("frontend"))
        assert logits.shape == (2, cfg.vocab), (arch, logits.shape)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        pos = jnp.int32(32)
        # decode needs cache padded to > pos; re-init at max_len 48 and splice prefill len
        dec_cache = spec.init_cache(2, 48)
        for k, v_ in cache.items():
            if k in dec_cache and dec_cache[k].ndim == v_.ndim and k != "length":
                if dec_cache[k].shape == v_.shape:
                    dec_cache[k] = v_
                else:  # pad seq dim (axis 2)
                    pads = [(0, a - b) for a, b in zip(dec_cache[k].shape, v_.shape)]
                    dec_cache[k] = jnp.pad(v_, pads)
        dec_cache["length"] = cache["length"]
        logits2, cache2 = spec.decode_step(params, dec_cache, tok, pos)
        assert logits2.shape == (2, cfg.vocab), (arch, logits2.shape)
        assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))), f"{arch}: decode NaN"
        print(f"PASS {arch:28s} loss={float(loss):.4f} gnorm={float(gnorm):.3f} params={spec.param_count():,}")
    except Exception as e:
        ok = False
        import traceback

        print(f"FAIL {arch}: {e}")
        traceback.print_exc()
sys.exit(0 if ok else 1)
