"""Microbench: what one NumPy call costs on this box, and the run length
where vectorizing starts to win over the scalar CPython float chain.

The turbo engine's central design bet (core/turbo.py) is that per-run
NumPy dispatch is NOT free: handing a ~28-event scheduling window to a
vector kernel pays the ufunc dispatch fee (argument parsing, dtype
resolution, buffer setup) per window, while the whole-trace prefix sum
pays it once per thread. This script measures the three numbers that
decide the trade on the current interpreter/NumPy/CPU combination:

  * scalar_ns_per_event — one `t += g` step of a plain Python float
    chain (the reference/fused engines' per-event timeline cost);
  * vector_ns_per_elem  — asymptotic per-element cost of np.cumsum on a
    long float64 array (the turbo engine's amortized regime);
  * dispatch_ns_per_call — the fixed fee of one tiny np.cumsum call
    after subtracting its per-element share.

Break-even run length = dispatch / (scalar - vector): below it a window
is cheaper to walk in pure Python, above it the vector call wins. On the
calibration boxes this lands in the hundreds — far above the measured
~2.7-event bursts and ~28-event ctx windows — which is why the turbo
walks fold bursts with integer counters instead of calling NumPy per
window.

  PYTHONPATH=src python scripts/dispatch_overhead.py
  PYTHONPATH=src python scripts/dispatch_overhead.py --json BENCH_sim.json

With --json the result block is merged into an existing report under
"dispatch_overhead" (the same in-place annotation protocol as
paired_bench.py), so it rides along in BENCH_sim.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

_BIG = 262_144  # long enough that dispatch is noise on the big call
_SMALL = 4      # typical burst scale: dispatch dominates
_REPS = 7       # best-of reps; min() rejects scheduler interference


def _best(f, inner: int) -> float:
    """Best-of-_REPS mean ns of one f() call, f looped `inner` times."""
    best = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter_ns()
        for _ in range(inner):
            f()
        dt = (time.perf_counter_ns() - t0) / inner
        if dt < best:
            best = dt
    return best


def measure() -> dict:
    rng = np.random.default_rng(0)
    big = rng.random(_BIG)
    small = big[:_SMALL].copy()
    big_out = np.empty_like(big)
    small_out = np.empty_like(small)
    gaps = big[:4096].tolist()

    def scalar_chain():
        t = 0.0
        for g in gaps:
            t += g
        return t

    scalar_ns = _best(scalar_chain, 16) / len(gaps)
    big_ns = _best(lambda: np.cumsum(big, out=big_out), 8)
    vector_ns = big_ns / _BIG
    small_ns = _best(lambda: np.cumsum(small, out=small_out), 4096)
    dispatch_ns = max(small_ns - _SMALL * vector_ns, 0.0)
    denom = scalar_ns - vector_ns
    break_even = dispatch_ns / denom if denom > 0 else float("inf")
    return {
        "scalar_ns_per_event": round(scalar_ns, 2),
        "vector_ns_per_elem": round(vector_ns, 3),
        "dispatch_ns_per_call": round(dispatch_ns, 1),
        "break_even_run_len": round(break_even, 1),
        "numpy": np.__version__,
    }


def _write_json(path: Path, results: dict) -> None:
    doc = {"dispatch_overhead": results}
    if path.exists():
        try:
            prior = json.loads(path.read_text())
        except ValueError:
            prior = None
        if isinstance(prior, dict):
            prior["dispatch_overhead"] = results
            doc = prior
    path.write_text(json.dumps(doc, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="merge the result into this report file under "
                         "'dispatch_overhead' (e.g. BENCH_sim.json)")
    args = ap.parse_args(argv)
    r = measure()
    print(f"# scalar float chain: {r['scalar_ns_per_event']} ns/event")
    print(f"# vector cumsum:      {r['vector_ns_per_elem']} ns/elem "
          f"(numpy {r['numpy']})")
    print(f"# dispatch fee:       {r['dispatch_ns_per_call']} ns/call")
    print(f"# break-even run len: {r['break_even_run_len']} events "
          f"(shorter runs are cheaper in pure Python)")
    if args.json:
        _write_json(Path(args.json), r)
        print(f"# merged into {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
