"""Paired A/B CPU-time benchmark: this checkout vs a worktree of another
commit, interleaved in the same time window so shared-core steal noise
cancels. Used to validate engine-perf acceptance criteria; results land in
BENCH_sim.json under "paired_vs_head" when run via --json.

  PYTHONPATH=src python scripts/paired_bench.py /tmp/pr2head [--json out]

Each cell is run alternately (A, B, A, B, ...) with ``--reps`` repetitions
and scored by best-of CPU time (time.process_time of a child worker),
which on a steal-heavy container is the stable signal (see DESIGN.md).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

CELLS = (
    ("bfs-dense", "skybyte-c"),
    ("bfs-dense", "skybyte-full"),
    ("tpcc", "skybyte-full"),
    ("srad", "skybyte-w"),
    ("tpcc", "base-cssd"),
    ("ycsb", "dram-only"),
)

_WORKER = r"""
import dataclasses, sys, time
from repro.configs.base import SimConfig
from repro.core.simulator import simulate
wl, variant, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = dataclasses.replace(SimConfig(), engine="batched")
t0 = time.process_time()
simulate(wl, variant, cfg, total_req=n, seed=0)
print(time.process_time() - t0)
"""


def run_cell(root: Path, wl: str, variant: str, n: int) -> float:
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, wl, variant, str(n)],
        capture_output=True, text=True, check=True,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    return float(out.stdout.strip())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_root", help="worktree of the commit to compare against")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default="")
    ap.add_argument("--cells", default="",
                    help="comma-separated substring filter on 'workload/variant' "
                         "(e.g. --cells bfs-dense runs just the ctx-bound cells)")
    args = ap.parse_args(argv)
    here = Path(__file__).resolve().parent.parent
    base = Path(args.baseline_root)
    cells = CELLS
    if args.cells:
        pats = [p.strip() for p in args.cells.split(",") if p.strip()]
        cells = tuple(c for c in CELLS
                      if any(pat in f"{c[0]}/{c[1]}" for pat in pats))
        if not cells:
            ap.error(f"--cells {args.cells!r} matches no cell; "
                     f"known: {', '.join(f'{w}/{v}' for w, v in CELLS)}")
    results = {}
    for wl, variant in cells:
        a_best = b_best = float("inf")
        for _ in range(args.reps):  # interleaved: same steal window for both
            b_best = min(b_best, run_cell(base, wl, variant, args.n))
            a_best = min(a_best, run_cell(here, wl, variant, args.n))
        speedup = b_best / max(a_best, 1e-9)
        results[f"{wl}/{variant}"] = {
            "head_cpu_s": round(b_best, 3),
            "this_cpu_s": round(a_best, 3),
            "speedup": round(speedup, 2),
        }
        print(f"{wl}/{variant}: head={b_best:.3f}s this={a_best:.3f}s "
              f"({speedup:.2f}x)", flush=True)
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
