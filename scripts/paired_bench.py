"""Paired A/B CPU-time benchmark, interleaved in the same time window so
shared-core steal noise cancels. Two modes:

  * checkout vs checkout (default): this checkout vs a worktree of
    another commit, both on the default engine. Validates cross-PR
    perf acceptance; results land in BENCH_sim.json under
    "paired_vs_head" when run via --json.

      PYTHONPATH=src python scripts/paired_bench.py /tmp/pr2head --json out

  * engine vs engine (--engines A,B): both engines inside THIS checkout,
    alternated per rep. Validates engine-perf acceptance (e.g. the turbo
    engine's paired-speedup criterion); results land under
    "paired_engines".

      PYTHONPATH=src python scripts/paired_bench.py --engines batched,turbo

Each cell is run alternately (A, B, A, B, ...) with ``--reps`` repetitions
and scored by best-of CPU time (time.process_time of a child worker),
which on a steal-heavy container is the stable signal (see DESIGN.md).

When --json points at an existing benchmark report (a JSON object), the
mode's result block is merged under its key instead of overwriting the
file, so both modes can annotate BENCH_sim.json in place.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

CELLS = (
    ("bfs-dense", "skybyte-c"),
    ("tpcc", "skybyte-c"),
    ("srad", "skybyte-cp"),
    ("bfs-dense", "skybyte-full"),
    ("tpcc", "skybyte-full"),
    ("srad", "skybyte-w"),
    ("tpcc", "base-cssd"),
    ("ycsb", "dram-only"),
)

# One untimed run warms the trace cache and each engine's derived-column
# caches, then the second run is timed: steady-state replay throughput,
# the same protocol as the in-process engine calibration. Both sides of
# every pairing get identical treatment.
_WORKER = r"""
import dataclasses, sys, time
from repro.configs.base import SimConfig
from repro.core.simulator import simulate
wl, variant, n, eng = sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4]
cfg = dataclasses.replace(SimConfig(), engine=eng) if eng else SimConfig()
simulate(wl, variant, cfg, total_req=n, seed=0)
t0 = time.process_time()
simulate(wl, variant, cfg, total_req=n, seed=0)
print(time.process_time() - t0)
"""


def run_cell(root: Path, wl: str, variant: str, n: int,
             engine: str = "") -> float:
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, wl, variant, str(n), engine],
        capture_output=True, text=True, check=True,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    return float(out.stdout.strip())


def _write_json(path: Path, key: str, results: dict) -> None:
    """Merge under ``key`` when the target is an existing JSON object
    (e.g. BENCH_sim.json); otherwise write a fresh single-key document."""
    doc = {key: results}
    if path.exists():
        try:
            prior = json.loads(path.read_text())
        except ValueError:
            prior = None
        if isinstance(prior, dict):
            prior[key] = results
            doc = prior
    path.write_text(json.dumps(doc, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_root", nargs="?", default="",
                    help="worktree of the commit to compare against "
                         "(omit when using --engines)")
    ap.add_argument("--engines", default="",
                    help="comma-separated pair BASE,CAND: baseline engine "
                         "vs candidate engine inside this checkout "
                         "(interleaved); reported speedup = "
                         "BASE_cpu / CAND_cpu, i.e. >1 means the "
                         "candidate is faster")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default="")
    ap.add_argument("--cells", default="",
                    help="comma-separated substring filter on 'workload/variant' "
                         "(e.g. --cells bfs-dense runs just the ctx-bound cells)")
    args = ap.parse_args(argv)
    here = Path(__file__).resolve().parent.parent
    eng_a = eng_b = ""
    if args.engines:
        pair = [e.strip() for e in args.engines.split(",")]
        if len(pair) != 2 or not all(pair):
            ap.error(f"--engines wants exactly two names (A,B), "
                     f"got {args.engines!r}")
        # validate against the simulator's registry so a typo fails here,
        # not per-cell inside the child workers
        sys.path.insert(0, str(here / "src"))
        from repro.core.simulator import ENGINES

        bad = sorted(set(pair) - set(ENGINES))
        if bad:
            ap.error(f"unknown engine(s): {', '.join(bad)}; "
                     f"valid engines: {', '.join(ENGINES)}")
        # the baseline engine rides in the b (head) slot so the reported
        # speedup keeps the default mode's meaning: >1 = candidate faster
        eng_b, eng_a = pair
        if args.baseline_root:
            ap.error("--engines compares inside this checkout; "
                     "baseline_root does not apply")
    elif not args.baseline_root:
        ap.error("need a baseline_root worktree or --engines A,B")
    base = Path(args.baseline_root) if args.baseline_root else here
    cells = CELLS
    if args.cells:
        pats = [p.strip() for p in args.cells.split(",") if p.strip()]
        cells = tuple(c for c in CELLS
                      if any(pat in f"{c[0]}/{c[1]}" for pat in pats))
        if not cells:
            ap.error(f"--cells {args.cells!r} matches no cell; "
                     f"known: {', '.join(f'{w}/{v}' for w, v in CELLS)}")
    results = {}
    for wl, variant in cells:
        a_best = b_best = float("inf")
        for _ in range(args.reps):  # interleaved: same steal window for both
            b_best = min(b_best, run_cell(base, wl, variant, args.n, eng_b))
            a_best = min(a_best, run_cell(here, wl, variant, args.n, eng_a))
        speedup = b_best / max(a_best, 1e-9)
        if args.engines:
            results[f"{wl}/{variant}"] = {
                f"{eng_b}_cpu_s": round(b_best, 3),
                f"{eng_a}_cpu_s": round(a_best, 3),
                "speedup": round(speedup, 2),
            }
            print(f"{wl}/{variant}: {eng_b}={b_best:.3f}s "
                  f"{eng_a}={a_best:.3f}s ({speedup:.2f}x)", flush=True)
        else:
            results[f"{wl}/{variant}"] = {
                "head_cpu_s": round(b_best, 3),
                "this_cpu_s": round(a_best, 3),
                "speedup": round(speedup, 2),
            }
            print(f"{wl}/{variant}: head={b_best:.3f}s this={a_best:.3f}s "
                  f"({speedup:.2f}x)", flush=True)
    if args.json:
        if args.engines:
            _write_json(Path(args.json), "paired_engines",
                        {"baseline": eng_b, "candidate": eng_a,
                         "cells": results})
        else:
            _write_json(Path(args.json), "paired_vs_head", results)
    return 0


if __name__ == "__main__":
    sys.exit(main())
