"""Export a latency-provenance event ring as a Chrome/Perfetto trace.

Runs one obs-enabled simulation (or reuses a saved stats dict) and writes
its device timeline — carved GC windows, GC suspends, bus convoys, fault
retry ladders, power-loss recovery barriers, compaction drains — as
trace-event JSON that chrome://tracing and https://ui.perfetto.dev open
directly. Channels become processes, dies become threads, and the
slowest-K host reads land on their own track with flow arrows back to
the device work that delayed them (core/obs.py ``to_perfetto``).

  PYTHONPATH=src python scripts/trace_export.py \
      --workload ycsb --variant base-cssd --total-req 200000 -o trace.json

  # convert a saved simulate() output that carries an "obs" block
  PYTHONPATH=src python scripts/trace_export.py \
      --from-json artifacts/run.json -o trace.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.base import ObsConfig, SimConfig  # noqa: E402
from repro.core.obs import to_perfetto  # noqa: E402
from repro.core.simulator import simulate  # noqa: E402
from repro.log import get_logger  # noqa: E402

_LOG = get_logger("scripts.trace_export")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="export an obs event ring as Chrome/Perfetto "
                    "trace-event JSON")
    ap.add_argument("--workload", default="ycsb")
    ap.add_argument("--variant", default="base-cssd")
    ap.add_argument("--total-req", type=int, default=200_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="batched",
                    choices=["reference", "batched"])
    ap.add_argument("--max-events", type=int, default=8192,
                    help="event-ring capacity (oldest events drop first)")
    ap.add_argument("--slow-k", type=int, default=32,
                    help="how many slowest host reads get flow tracks")
    ap.add_argument("--from-json", default="",
                    help="skip simulation: read a saved stats dict (or a "
                         "bare obs block) from this JSON file")
    ap.add_argument("-o", "--out", default="trace.json")
    args = ap.parse_args(argv)

    if args.from_json:
        doc = json.loads(Path(args.from_json).read_text())
        block = doc.get("obs", doc)  # accept a full stats dict or the block
        if "events" not in block:
            print(f"trace_export: {args.from_json} has no obs event block "
                  f"(run with SimConfig.obs.enabled)", file=sys.stderr)
            return 1
        title = Path(args.from_json).stem
    else:
        cfg = dataclasses.replace(
            SimConfig(), engine=args.engine,
            obs=ObsConfig(enabled=True, max_events=args.max_events,
                          slow_k=args.slow_k))
        out = simulate(args.workload, args.variant, cfg,
                       total_req=args.total_req, seed=args.seed)
        block = out["obs"]
        cons = block["conservation"]
        if not cons["pass"]:  # never expected; surface loudly if it is
            _LOG.warning("conservation check FAILED: %s", cons)
        title = f"{args.workload}/{args.variant}"

    trace = to_perfetto(block, title=title)
    Path(args.out).write_text(json.dumps(trace))
    ev = block["events"]
    print(f"# trace_export: {len(trace['traceEvents'])} trace events "
          f"({ev['emitted']} device events emitted, {ev['dropped']} "
          f"dropped by the ring) -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
