"""End-to-end training driver (deliverable b): trains the smollm-135m
reduced config for a few hundred steps on the synthetic pipeline, with
checkpointing and (optionally) a simulated crash + recovery.

  PYTHONPATH=src python examples/train_lm.py            # ~200 steps
  PYTHONPATH=src python examples/train_lm.py --drill    # crash + resume

This is a thin veneer over repro.launch.train (the real launcher) so the
example and production path cannot drift.
"""
import sys

from repro.launch import train as train_launcher


def main() -> None:
    drill = "--drill" in sys.argv
    base = [
        "--arch", "smollm-135m", "--steps", "200", "--seq", "128",
        "--batch", "8", "--accum", "2", "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_example_ckpt", "--ckpt-every", "50",
    ]
    if drill:
        sys.argv = ["train", *base, "--fail-at", "120"]
        try:
            train_launcher.main()
        except SystemExit as e:
            print(f"[example] crashed as requested (exit {e.code}); resuming...")
        sys.argv = ["train", *base, "--resume"]
        train_launcher.main()
    else:
        sys.argv = ["train", *base]
        train_launcher.main()


if __name__ == "__main__":
    main()
