"""Serving example: SkyByte tiered KV vs dense baseline on the same
requests — prints the paper-style serving metrics.

  PYTHONPATH=src python examples/serve_tiered.py
"""
import sys

from repro.launch import serve as serve_launcher


def main() -> None:
    for tiering in ("baseline", "skybyte"):
        sys.argv = [
            "serve", "--arch", "qwen3-1.7b", "--requests", "4",
            "--prompt-len", "24", "--new-tokens", "16",
            "--tiering", tiering,
        ]
        serve_launcher.main()


if __name__ == "__main__":
    main()
