"""The paper's headline experiment in miniature: the §VI-A variant grid on
two workloads, printing the Fig 14-style normalized execution times.

  PYTHONPATH=src python examples/simulate_skybyte.py
"""
from repro.configs.base import VARIANTS
from repro.core.simulator import simulate

N = 120_000
for wl in ("bc", "srad"):
    base = None
    print(f"--- {wl} ---")
    for v in VARIANTS:
        r = simulate(wl, v, total_req=N)
        if v == "base-cssd":
            base = r
        print(f"{v:14s} norm_exec={r['exec_ns']/base['exec_ns']:.3f} "
              f"amat={r['amat_ns']:8.1f}ns flashwr={r['flash_write_pages']:6d}pg "
              f"cs={r['ctx_switches']}")
