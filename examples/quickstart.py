"""Quickstart: the three layers of the repo in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. paper-faithful CXL-SSD simulator — one workload, Base vs SkyByte-Full
2. a model from the assigned pool — one training step
3. the TPU-native SkyByte tiering runtime — paged+logged decode equals
   dense decode bit-for-bit
"""
import jax
import jax.numpy as jnp

from repro.configs import OptimConfig, get_reduced
from repro.core.simulator import simulate
from repro.core.tiering import TieredKVConfig
from repro.launch.steps import build_train_step, make_train_state
from repro.models.api import ModelSpec
from repro.serving.engine import Request, TieredEngine

print("=== 1. SkyByte simulator (paper Fig 14, one workload, small run) ===")
base = simulate("srad", "base-cssd", total_req=60_000)
full = simulate("srad", "skybyte-full", total_req=60_000)
print(f"srad: Base-CSSD {base['exec_ns']/1e6:.1f} ms -> SkyByte-Full "
      f"{full['exec_ns']/1e6:.1f} ms  ({base['exec_ns']/full['exec_ns']:.2f}x)  "
      f"amat {base['amat_ns']:.0f} -> {full['amat_ns']:.0f} ns")

print("=== 2. one training step (smollm-135m, reduced) ===")
spec = ModelSpec(get_reduced("smollm-135m"))
state = make_train_state(spec, jax.random.PRNGKey(0))
step = jax.jit(build_train_step(spec, OptimConfig(lr=1e-3), accum_steps=2))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      spec.cfg.vocab, jnp.int32)}
state, metrics = step(state, batch)
print(f"loss={float(metrics['loss']):.4f} grad_norm={float(metrics['grad_norm']):.3f}")

print("=== 3. tiered paged-KV serving (SkyByte runtime) ===")
spec = ModelSpec(get_reduced("qwen3-1.7b"))
params = spec.init(jax.random.PRNGKey(0))
kv = TieredKVConfig(page_size=8, n_hbm_pages=12, max_requests=2,
                    max_pages_per_req=8, log_slots=32, batch=2,
                    promote_pages_per_step=2)
eng = TieredEngine(spec, params, kv)
eng.add_request(Request(rid=0, prompt=list(range(5, 25)), max_new_tokens=12))
eng.add_request(Request(rid=1, prompt=list(range(30, 45)), max_new_tokens=12))
stats = eng.run(200)
print(f"decoded {stats.decoded_tokens} tokens; ctx-switches(parks)={stats.parks} "
      f"promoted={stats.promoted_pages} compactions={stats.compactions}")
print("ok")
