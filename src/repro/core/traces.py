"""Synthetic LLC-miss trace generation for the 7 paper workloads (Table I).

The paper replays PIN instruction traces through a simulated cache
hierarchy; the CXL-SSD only ever sees the resulting *off-chip* access
stream. We generate that stream directly, parameterized by the published
per-workload characteristics:

  * memory footprint (Table I), scaled by SimConfig.scale with all
    capacity *ratios* preserved (the paper itself scales Samsung's 2TB
    prototype down to 128GB the same way);
  * write ratio (Table I);
  * LLC MPKI (Table I) -> mean compute gap between consecutive misses
    (1000/MPKI instructions at ~2 IPC & 4 GHz);
  * per-page line-access locality matched to Fig. 5/6: most workloads
    touch <40% of the 64 lines in >75% of pages — drawn per page from a
    workload-specific categorical over line-coverage buckets;
  * hot/cold page skew (drives the promotion benefit, Fig. 14 per-workload
    spread): fraction ``hot_frac`` of pages receive ``hot_mass`` of
    accesses.

Each thread gets an independent stream (same distribution, different seed),
matching the paper's per-thread trace capture.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import tempfile
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.log import get_logger


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    footprint_bytes: int  # Table I
    write_ratio: float  # Table I
    mpki: float  # Table I
    # Fig 5/6 locality: probability a page's touched-line coverage falls in
    # (0-25%, 25-50%, 50-75%, 75-100%] buckets
    line_cov: tuple
    hot_frac: float = 0.2  # fraction of pages that are "hot" (read set)
    hot_mass: float = 0.8  # fraction of READ accesses hitting hot pages
    seq_run: int = 4  # mean # of consecutive lines per page visit (spatial)
    # Writes: sparse per page (Fig 6: mostly <40% dirty lines) but
    # *temporally recurrent* over a "warm write set" whose recurrence
    # interval exceeds the page cache's residency yet fits the write log's
    # coalescing window — the paper's "temporally sparse writes" (bc, dlrm)
    # that the log wins on. Warm set is disjoint from the read-hot set.
    write_warm_frac: float = 0.08  # fraction of pages forming the warm set
    write_warm_mass: float = 0.75  # fraction of writes hitting the warm set
    # Medium-hot read tier: too big for SSD DRAM, sized for the 4x host
    # DRAM budget — the locality band that adaptive page *promotion*
    # captures (SkyByte-P's 1.84x / Full's 75%-of-DRAM headline).
    med_frac: float = 0.18  # fraction of pages in the medium tier
    med_share: float = 0.85  # share of non-hot reads that hit the medium tier


# Table I + Fig 5/6-informed locality profiles. hot_frac is tuned so the
# read-hot set is ~1.5-3x the (scaled) SSD DRAM cache — reproducing Fig 3's
# ">90% of requests under 200ns, microsecond tail" shape.
# Profiles calibrated (scripts/calibrate_traces.py methodology) so that
# Base-CSSD's DRAM-vs-CXL slowdown per workload lands inside the paper's
# Fig 2 range (1.5-31.4x) with >80% SSD-DRAM hit rates (Fig 3 shape).
WORKLOADS: Dict[str, WorkloadSpec] = {
    "bfs-dense": WorkloadSpec("bfs-dense", int(9.13e9), 0.25, 122.9,
                              (0.70, 0.15, 0.10, 0.05), 0.015, 0.93, 2, 0.05, 0.95, 0.18, 0.7),
    "bc": WorkloadSpec("bc", int(8.18e9), 0.11, 39.4,
                       (0.75, 0.12, 0.08, 0.05), 0.015, 0.92, 2, 0.077, 0.97, 0.18, 0.75),
    "radix": WorkloadSpec("radix", int(9.60e9), 0.29, 7.1,
                          (0.20, 0.20, 0.25, 0.35), 0.015, 0.92, 16, 0.06, 0.97, 0.16, 0.75),
    "srad": WorkloadSpec("srad", int(8.16e9), 0.24, 7.5,
                         (0.60, 0.25, 0.10, 0.05), 0.015, 0.92, 4, 0.06, 0.97, 0.18, 0.75),
    "ycsb": WorkloadSpec("ycsb", int(9.61e9), 0.05, 92.2,
                         (0.80, 0.10, 0.06, 0.04), 0.015, 0.95, 1, 0.0245, 0.92, 0.16, 0.75),
    "tpcc": WorkloadSpec("tpcc", int(15.77e9), 0.36, 1.0,
                         (0.55, 0.20, 0.15, 0.10), 0.015, 0.92, 4, 0.0105, 0.98, 0.1, 0.75),
    "dlrm": WorkloadSpec("dlrm", int(12.35e9), 0.32, 5.1,
                         (0.75, 0.15, 0.06, 0.04), 0.015, 0.94, 1, 0.047, 0.97, 0.13, 0.75),
}

LINES_PER_PAGE = 64
_IPC = 2.0
_GHZ = 4.0


def gen_thread_trace(
    spec: WorkloadSpec, n_req: int, seed: int, scale: int, page_bytes: int = 4096
) -> Dict[str, np.ndarray]:
    """One thread's off-chip stream.

    Returns dict of arrays: page (int64), line (int8), write (bool),
    gap_ns (float32) — compute time between this and the previous request.
    """
    rng = np.random.default_rng(seed)
    n_pages = max(int(spec.footprint_bytes // scale // page_bytes), 64)
    n_hot = max(int(n_pages * spec.hot_frac), 1)

    # per-page line coverage (how many of the 64 lines this page ever uses)
    bucket_hi = np.array([0.25, 0.50, 0.75, 1.00])
    pg_bucket = rng.choice(4, size=n_pages, p=np.asarray(spec.line_cov))
    pg_cov = np.maximum(
        1, (bucket_hi[pg_bucket] * rng.uniform(0.4, 1.0, n_pages) * LINES_PER_PAGE)
    ).astype(np.int8)

    # page visit sequence: hot/cold mixture; each visit emits a short
    # sequential run of lines (spatial locality). Visits are all-read or
    # all-write; write visits use a flatter page distribution and short runs.
    mean_run = max(spec.seq_run, 1)
    n_visits = max(n_req // mean_run, 1)
    # visits are weighted by run length; solve for the visit-level write
    # probability that yields Table I's REQUEST-level write ratio.
    # run = 1 + min(G, 15), G ~ Geom(1/mean_run):
    #   E[read run]  = 1 + (1 - (1-p)^15)/p
    #   E[write run] = 2 exactly (write runs are clipped at 2; G >= 1)
    if mean_run > 1:
        pg = 1.0 / mean_run
        r_run = 1.0 + (1.0 - (1.0 - pg) ** 15) / pg
        w_run = 2.0
    else:
        r_run = w_run = 1.0
    wr = spec.write_ratio
    p_wv = wr * r_run / (w_run * (1 - wr) + wr * r_run)
    visit_write = rng.random(n_visits) < p_wv
    # page-space layout: [hot | warm-write | medium | cold]
    # reads:  hot (hot_mass) -> medium (med_share of rest) -> cold tail
    # writes: warm (write_warm_mass) -> cold tail; disjoint from read-hot
    n_warm = max(int(n_pages * spec.write_warm_frac), 1)
    n_med = max(int(n_pages * spec.med_frac), 1)
    med0 = n_hot + n_warm
    cold0 = med0 + n_med
    n_cold = max(n_pages - cold0, 1)
    is_hot = rng.random(n_visits) < np.where(
        visit_write, spec.write_warm_mass, spec.hot_mass
    )
    is_med = (~is_hot) & (rng.random(n_visits) < spec.med_share)
    cold_pages = cold0 + rng.integers(0, n_cold, n_visits)
    read_pages = np.where(
        is_hot,
        rng.integers(0, n_hot, n_visits),
        np.where(is_med, med0 + rng.integers(0, n_med, n_visits), cold_pages),
    )
    write_pages = np.where(
        is_hot, n_hot + rng.integers(0, n_warm, n_visits), cold_pages
    )
    pages = np.where(visit_write, write_pages, read_pages)
    run_len = (
        1 + rng.geometric(1.0 / mean_run, n_visits)
        if mean_run > 1
        else np.ones(n_visits, np.int64)
    )
    run_len = np.minimum(run_len, 16)
    run_len = np.where(visit_write, np.minimum(run_len, 2), run_len)

    page_arr = np.repeat(pages, run_len)[:n_req]
    # line index within the page's covered set, walking sequentially per run
    start = rng.integers(0, LINES_PER_PAGE, n_visits)
    # per-run 0..r-1 ramps, vectorized: global position minus own run's start
    total = int(run_len.sum())
    run_starts = np.repeat(np.cumsum(run_len) - run_len, run_len)
    offsets = (np.arange(total) - run_starts)[:n_req]
    base = np.repeat(start, run_len)[:n_req]
    cov = pg_cov[page_arr]
    line_arr = ((base + offsets) % np.maximum(cov, 1)).astype(np.int8)

    write_arr = np.repeat(visit_write, run_len)[:n_req]
    # writes revisit a small per-page dirty set (counters / hot fields — the
    # temporal write reuse the log's newest-wins coalescing collapses; Base
    # rewrites the whole 4KB page on every eviction instead)
    wcov = np.minimum(np.maximum(cov, 1), 4)
    wline = ((page_arr * 7 + offsets) % wcov).astype(np.int8)
    line_arr = np.where(write_arr, wline, line_arr)
    # compute gap: 1000/MPKI instructions at IPC=2, 4GHz, exponential jitter
    mean_gap_ns = (1000.0 / max(spec.mpki, 0.1)) / _IPC / _GHZ
    gap_arr = rng.exponential(mean_gap_ns, len(page_arr)).astype(np.float32)

    n = len(page_arr)
    if n < n_req:  # top up (rare)
        reps = n_req // n + 1
        page_arr = np.tile(page_arr, reps)[:n_req]
        line_arr = np.tile(line_arr, reps)[:n_req]
        write_arr = np.tile(write_arr, reps)[:n_req]
        gap_arr = np.tile(gap_arr, reps)[:n_req]
    return {
        "page": page_arr.astype(np.int64),
        "line": line_arr,
        "write": write_arr,
        "gap_ns": gap_arr,
        "n_pages": n_pages,
    }


# ---------------------------------------------------------------------------
# Trace caching. A benchmark grid asks for the same
# (workload, threads, n_req, seed, scale) stream once per *variant*
# (fig14's 8-variant row shares two thread counts), and every fresh
# process (CI parity smoke, engine calibration, paired benchmarks) pays
# full generation again. Two layers fix that:
#   * an in-process lru_cache (hot within one grid worker), and
#   * an on-disk artifact cache (artifacts/traces/, compressed npz),
#     keyed by the generation parameters plus a fingerprint of THIS file —
#     editing the generator invalidates stale traces automatically. Writes
#     are atomic (tmp + rename) so parallel grid workers can race safely,
#     and only streams up to _DISK_CACHE_MAX_EVENTS are persisted (larger
#     ones are cheap relative to their simulation and would bloat
#     artifacts/). Artifacts are stored UNcompressed: the load path sits
#     on the paired-benchmark critical path and zlib decompression cost
#     (~20 ms per 200k-event stream) dwarfs the disk saving on a local
#     cache. The directory's TOTAL size is what is bounded instead
#     (REPRO_TRACE_CACHE_GB, default 2 GB): past the cap the least-
#     recently-used npz files are evicted after each store — and since
#     the filename key fingerprints this file, stale compressed
#     artifacts from older generators age out through the same path.
#     Each eviction pass logs a one-line count/bytes summary (logger
#     "repro.core.traces") so sweep jobs can see cache churn.
# Callers treat the returned arrays as read-only (the simulator copies
# the one column it re-types, gap_ns -> float64).
# ---------------------------------------------------------------------------

_TRACE_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "traces"
_DISK_CACHE_MAX_EVENTS = 8_000_000
# Total on-disk budget for artifacts/traces/ (GB). Grid sweeps across many
# (workload, threads, n_req, scale) combinations used to grow the
# directory without bound; beyond the cap the least-recently-USED npz
# artifacts are evicted (cache hits refresh mtime, so hot streams survive
# sweeps that churn one-off cells). REPRO_TRACE_CACHE_GB overrides;
# <= 0 disables the bound.
_DISK_CACHE_DEFAULT_GB = 2.0

_LOG = get_logger(__name__)


def _disk_cache_cap_bytes() -> int:
    raw = os.environ.get("REPRO_TRACE_CACHE_GB", "")
    try:
        gb = float(raw) if raw else _DISK_CACHE_DEFAULT_GB
    except ValueError:
        gb = _DISK_CACHE_DEFAULT_GB
    return int(gb * (1 << 30))


def _evict_lru(keep: Path) -> int:
    """Shrink the trace cache below the size cap, oldest-mtime first
    (mtime is refreshed on every cache hit, so eviction order is LRU).
    Best-effort: races with parallel grid workers just skip entries.
    Returns the number of artifacts evicted and logs a one-line summary
    when pruning actually triggered (it used to be silent, which made
    cache-thrash during grid sweeps invisible)."""
    cap = _disk_cache_cap_bytes()
    if cap <= 0:
        return 0
    entries = []
    total = 0
    for p in _TRACE_DIR.glob("*.npz"):
        try:
            st = p.stat()
        except OSError:  # concurrently evicted by another worker
            continue
        entries.append((st.st_mtime, st.st_size, p))
        total += st.st_size
    evicted = 0
    freed = 0
    if total > cap:
        for _, size, p in sorted(entries):
            if p == keep:  # never evict the artifact just written
                continue
            try:
                p.unlink()
            except OSError:
                continue
            evicted += 1
            freed += size
            total -= size
            if total <= cap:
                break
    if evicted:
        _LOG.info(
            "trace cache: evicted %d artifact(s), freed %.1f MiB "
            "(cap %.2f GiB, now %.1f MiB)", evicted, freed / (1 << 20),
            cap / (1 << 30), total / (1 << 20))
    return evicted


@functools.lru_cache(maxsize=1)
def _source_fingerprint() -> str:
    return hashlib.sha1(Path(__file__).read_bytes()).hexdigest()[:12]


def _disk_cache_enabled() -> bool:
    return os.environ.get("REPRO_TRACE_CACHE", "1") != "0"


def _load_traces(path: Path, n_threads: int) -> List[Dict[str, np.ndarray]]:
    with np.load(path) as z:
        n_pages = z["n_pages"]
        return [
            {
                "page": z[f"page_{t}"],
                "line": z[f"line_{t}"],
                "write": z[f"write_{t}"],
                "gap_ns": z[f"gap_{t}"],
                "n_pages": int(n_pages[t]),
            }
            for t in range(n_threads)
        ]


def _store_traces(path: Path, traces: List[Dict[str, np.ndarray]]) -> None:
    arrays = {"n_pages": np.array([tr["n_pages"] for tr in traces])}
    for t, tr in enumerate(traces):
        arrays[f"page_{t}"] = tr["page"]
        arrays[f"line_{t}"] = tr["line"]
        arrays[f"write_{t}"] = tr["write"]
        arrays[f"gap_{t}"] = tr["gap_ns"]
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            # uncompressed: load time beats disk footprint for a local,
            # LRU-bounded cache (see the cache design note above)
            np.savez(f, **arrays)
        os.replace(tmp, path)  # atomic vs concurrent grid workers
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


@functools.lru_cache(maxsize=4)
def gen_traces(
    workload: str, n_threads: int, n_req: int, seed: int = 0, scale: int = 64
) -> List[Dict[str, np.ndarray]]:
    spec = WORKLOADS[workload]
    use_disk = (_disk_cache_enabled()
                and n_threads * n_req <= _DISK_CACHE_MAX_EVENTS)
    path = _TRACE_DIR / (
        f"{workload}_{n_threads}t_{n_req}r_{seed}s_{scale}x_"
        f"{_source_fingerprint()}.npz")
    if use_disk and path.exists():
        try:
            loaded = _load_traces(path, n_threads)
            try:  # LRU touch: a hit must not be the next eviction victim
                os.utime(path)
            except OSError:
                pass
            return loaded
        except Exception as e:
            # Corrupt/truncated artifact (killed grid worker mid-rename on
            # a non-atomic filesystem, disk-full tail, manual tampering):
            # EVICT it, not just skip it — a bad entry left in place would
            # be re-parsed (and re-fail) on every later run, and it still
            # occupies LRU budget. Regeneration below overwrites anyway,
            # but unlinking first also covers read-only-artifact setups
            # where the store is best-effort.
            try:
                os.unlink(path)
            except OSError:
                pass
            _LOG.warning(
                "trace cache: evicted corrupt artifact %s (%s: %s); "
                "regenerating", path.name, type(e).__name__, e)
    traces = [
        gen_thread_trace(spec, n_req, seed * 1000 + t, scale) for t in range(n_threads)
    ]
    if use_disk:
        try:
            _store_traces(path, traces)
            _evict_lru(keep=path)
        except OSError:  # read-only checkout etc: caching is best-effort
            pass
    return traces
