"""Block-granular flash backend: FTL mapping, physical-address service
routing, GC victim selection, wear leveling, hot/cold write frontiers,
and write-amplification / tail-latency accounting.

The legacy ``Ftl`` in ``ssd.py`` is a free-page *counter*: GC fires at a
utilization threshold with a fixed 8-page migration cost and a logical
page-hash channel/die pick that cannot depend on what the device actually
wrote. This module replaces it (``SimConfig.ftl_backend = "block"``, the
default) with real erase-block state, so the write log's coalescing
*measurably* reduces write amplification and GC-induced tail latency:

  * **Geometry** — physical space is the logical page space times
    ``1 + op_ratio`` (over-provisioning), carved into erase blocks of
    ``pages_per_block`` pages. Every logical page is preconditioned
    identity-mapped (sequentially, blocks sealed), exactly like a device
    whose data set is resident; the spare blocks are the initial free
    pool.
  * **Physical service routing** — ``phys_loc(page)`` derives the
    channel/die every read and program queues on from the *block* the
    FTL placed the page in (``blk_loc``: ``blk % n_channels``,
    ``(blk // n_channels) % DIES_PER_CHANNEL`` — the same derivation GC
    busy windows use, so a page migrated into the GC frontier is
    subsequently served from the die GC programmed it to). The legacy
    backend keeps the historical logical page-hash striping
    (``Channels.logical_loc``) bit-for-bit.
  * **Log-structured mapping** — ``l2p``/``p2l`` plus a dense per-page
    valid bitmap and per-block valid counts. A host program invalidates
    the old physical slot and appends to a *host frontier* block; GC
    migrations append to a separate *GC frontier* (the standard
    greedy-cleaning layout). With ``SimConfig.hotcold`` the host
    frontier splits in two by rewrite heat: a program whose previous
    copy still sits in an OPEN block (rewritten within one
    frontier-block lifetime) is hot and lands on the hot frontier, so
    hot pages die together and hot blocks seal near-empty.
  * **GC victim policies** — ``gc_policy="greedy"`` picks the sealed
    block with the fewest valid pages; ``"cost-benefit"`` ranks sealed
    blocks by the classic (1-u)/(1+u) * age score (age in seal-sequence
    ticks), which beats greedy when hot and cold data age at different
    rates. Both are deterministic (NumPy argmin/argmax, first-minimal
    tie-break).
  * **Wear-aware allocation** — with ``SimConfig.wear_leveling`` a
    sealed frontier draws its replacement from the free pool by lowest
    erase count (block-id tie-break) instead of LIFO pop, rotating the
    spare pool and flattening the per-block erase spread.
  * **Migration-proportional GC cost** — each collection occupies the
    victim block's die for ``erase_ns + live * read_ns`` and writes each
    live page through the GC frontier's channel/die (``program_ns`` +
    bus transfer per page). Fewer live pages — what log coalescing buys —
    means measurably shorter busy windows, which Algorithm 1's estimator
    observes exactly like any other queued work; the windows are also
    recorded in ``DeviceState.gc_die_until`` so reads that queue behind
    them are attributed as GC pauses (``Stats.gc_pause_ns_total``).
  * **Wear / WAF accounting** — per-block erase counts and a migrated-
    page counter; ``Stats.waf`` is (host programs + migrated pages) /
    host programs.
  * **Superblock striping** (``SimConfig.superblock``) — route the
    physical PAGE instead of the block (``loc_div`` = 1 vs ``ppb``), so
    a block's pages fan across channels/dies: stripe-parallel reads,
    but a GC victim's blast radius spans every die the stripe touches
    (``_gc_once_super``). GC windows also refill the per-die bounded
    suspend budget (``gc_susp_left``/``gc_windows``) that the die-level
    QoS arbiter (core/qos.py) consumes.

Exactness contract with the batched engine: every flash program happens
on a *boundary* path (dirty evictions, compaction drains, Base-CSSD
write-allocate fills), which both engines execute through the SAME
``on_flash_write`` method of the shared policy object at the same
sequence points — ``on_flash_write`` now also charges the program's
bus/die timing at the destination frontier's physical location, so there
is nothing engine-specific to transcribe and parity is structural
(enforced by tests/test_flash.py and the tests/test_engine.py grid).
Mapping changes only ever happen on these boundary paths, which is what
keeps the engines' cached classification machinery untouched by routing.
"""
from __future__ import annotations

import heapq
import os
from typing import List, Tuple

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL
from repro.core.ssd import TRANSFER_NS

GC_POLICIES = ("greedy", "cost-benefit")


def blk_loc(blk: int, n_channels: int) -> Tuple[int, int]:
    """Physical placement of an erase block: (channel, die). Consecutive
    blocks stripe across channels, then dies, so every block below
    ``n_channels * DIES_PER_CHANNEL`` owns a distinct (channel, die) pair
    — maximal die-level parallelism for block-granular placement. The
    ONE derivation shared by read/program routing (``BlockFtl.phys_loc``)
    and GC busy-window placement."""
    return blk % n_channels, (blk // n_channels) % DIES_PER_CHANNEL


class FlashState:
    """Dense block-FTL state (lives on DeviceState — single source of
    truth for both replay engines). Scalar-hot arrays carry memoryview
    mirrors, same trick as the rest of DeviceState."""

    __slots__ = (
        "ppb", "n_blocks", "n_phys", "reserve",
        "l2p", "l2p_mv", "p2l", "p2l_mv",
        "pvalid", "pvalid_mv", "blk_valid", "blk_valid_mv",
        "blk_state", "blk_state_mv", "blk_seal", "blk_seal_mv",
        "blk_erase", "blk_erase_mv", "blk_gc", "blk_gc_mv",
        "free", "seal_seq",
        "host_blk", "host_slot", "gc_blk", "gc_slot",
        "hot_blk", "hot_slot", "heat_win",
    )

    def __init__(self, page_space: int, pages_per_block: int,
                 op_ratio: float, hotcold: bool = False):
        ppb = max(int(pages_per_block), 2)
        lblocks = -(-page_space // ppb)  # ceil
        # spare floor: every open frontier (host [+hot] + GC) plus the
        # 2-block GC reserve must be coverable even at tiny test geometries
        n_frontiers = 3 if hotcold else 2
        n_blocks = max(int(lblocks * (1.0 + op_ratio)) + 1,
                       lblocks + n_frontiers + 2)
        self.ppb = ppb
        self.n_blocks = n_blocks
        self.n_phys = n_blocks * ppb
        self.reserve = max(2, (n_blocks - lblocks) // 8)
        # --- precondition: identity-map every logical page, seal those
        # blocks (ages 1..lblocks in seal order). Identity keeps each
        # workload's contiguous page tiers (hot / warm-write / medium /
        # cold ranges) CLUSTERED in blocks: rewrite-heavy warm ranges
        # invalidate whole blocks, which is what gives greedy GC its
        # near-empty victims (the log-size -> WAF coupling). Under
        # blk_loc any range of more than a few hundred pages still spans
        # dozens of distinct (channel, die) pairs, so miss parallelism
        # matches the logical stripe's for every Table I access pattern
        # (traces have line-level runs, not cross-page sequential scans).
        idx = np.arange(page_space)
        self.l2p = np.full(page_space, -1, np.int64)
        self.l2p[:] = idx
        self.p2l = np.full(self.n_phys, -1, np.int64)
        self.p2l[:page_space] = idx
        self.pvalid = np.zeros(self.n_phys, bool)
        self.pvalid[:page_space] = True
        self.blk_valid = np.zeros(n_blocks, np.int64)
        full_blocks = page_space // ppb
        self.blk_valid[:full_blocks] = ppb
        if full_blocks < lblocks:
            self.blk_valid[full_blocks] = page_space - full_blocks * ppb
        self.blk_state = np.zeros(n_blocks, np.int8)  # 0 free/1 open/2 sealed/3 bad
        self.blk_state[:lblocks] = 2
        self.blk_seal = np.zeros(n_blocks, np.int64)
        self.blk_seal[:lblocks] = np.arange(1, lblocks + 1)
        self.blk_erase = np.zeros(n_blocks, np.int64)
        # which open/sealed blocks hold GC-written data: GC migrates the
        # coldest survivors, so a copy GC wrote must never make the next
        # rewrite look "hot" (the GC frontier is an open block and would
        # otherwise pass the heat test). Set when a frontier opens a
        # block, per frontier kind.
        self.blk_gc = np.zeros(n_blocks, bool)
        self.seal_seq = lblocks
        # free pool: pop() hands out ascending block ids
        self.free: List[int] = list(range(n_blocks - 1, lblocks - 1, -1))
        self.l2p_mv = memoryview(self.l2p)
        self.p2l_mv = memoryview(self.p2l)
        self.pvalid_mv = memoryview(self.pvalid)
        self.blk_valid_mv = memoryview(self.blk_valid)
        self.blk_state_mv = memoryview(self.blk_state)
        self.blk_seal_mv = memoryview(self.blk_seal)
        self.blk_erase_mv = memoryview(self.blk_erase)
        self.blk_gc_mv = memoryview(self.blk_gc)
        self.host_blk = self.free.pop()
        self.host_slot = 0
        self.blk_state_mv[self.host_blk] = 1
        self.gc_blk = self.free.pop()
        self.gc_slot = 0
        self.blk_state_mv[self.gc_blk] = 1
        self.blk_gc_mv[self.gc_blk] = True
        if hotcold:
            self.hot_blk = self.free.pop()
            self.hot_slot = 0
            self.blk_state_mv[self.hot_blk] = 1
        else:
            self.hot_blk = -1
            self.hot_slot = 0
        # rewrite-heat window (hotcold): a program is "hot" when its
        # previous copy lives in an open block OR one sealed within the
        # last heat_win seal ticks — i.e. the page's rewrite interval is
        # shorter than ~a quarter of the data set's block count. Scales
        # with the device: eviction- and compaction-driven rewrite
        # intervals grow with the footprint, and a fixed one-block window
        # would classify everything cold.
        self.heat_win = max(8, lblocks // 4)


class BlockFtl:
    """Block-granular FTL policy over the shared FlashState.

    Interface-compatible with the legacy ``ssd.Ftl``: both engines call
    ``on_flash_write(now, page)`` once per host flash program. Unlike the
    legacy counter, the block FTL also CHARGES the program's bus/die
    timing itself — the destination (the frontier block the page lands
    in, hot or cold) is only known here, and physical routing means the
    timing must land on that block's die."""

    def __init__(self, cfg: SimConfig, state, channels):
        if cfg.gc_policy not in GC_POLICIES:
            raise ValueError(f"unknown SimConfig.gc_policy: {cfg.gc_policy!r}")
        self.cfg = cfg
        self.s = state
        self.fs = state.flash
        self.channels = channels
        self.greedy = cfg.gc_policy == "greedy"
        self.wear_level = bool(cfg.wear_leveling)
        self.read_ns = cfg.flash.read_ns
        self.program_ns = cfg.flash.program_ns
        self.erase_ns = cfg.flash.erase_ns
        self.n_channels = cfg.n_channels
        # superblock striped-frontier placement: pages of a block stripe
        # page-by-page across channels then dies instead of the whole
        # block living on blk_loc's single die. loc_div is the service-
        # path divisor that unifies both derivations — location is
        # blk_loc(pp // loc_div): loc_div == ppb collapses pp to its
        # block id (per-die blocks), loc_div == 1 routes the physical
        # page itself (striped). The engines' inlined read sites use the
        # same divisor (engine._span_env), so the default layout stays
        # bit-identical with zero new branches.
        self.superblock = bool(cfg.superblock)
        self.loc_div = 1 if self.superblock else self.fs.ppb
        self.susp_max = cfg.gc_suspend_max
        # promotion-placement interplay (superblock + hotcold + the
        # paper's counter-threshold promotion): a page the estimator is
        # one access away from promoting will leave flash for host DRAM —
        # its flash rewrite stream is about to STOP, so letting it anchor
        # the hot frontier seals "hot" stripes around pages that never
        # die there. Gated to superblock mode so the per-die hotcold
        # layout stays the PR 5 behaviour bit-for-bit.
        self._promo_gate = (self.superblock and cfg.hotcold
                            and cfg.enable_promotion
                            and cfg.promo_policy == "skybyte")
        self._acc_mv = state.acc._mv if self._promo_gate else None
        self._promo_thr = cfg.promote_threshold
        # Greedy victim selection keeps a lazy min-heap of (valid, block)
        # over sealed blocks instead of an argmin scan per GC round: an
        # entry is pushed whenever a block seals and whenever a SEALED
        # block loses a valid page, so for every sealed block the heap
        # always holds an entry with its CURRENT count (counts only ever
        # decrease while sealed; stale entries are strictly larger and
        # are lazily discarded when they surface). Lexicographic (valid,
        # block) order reproduces the argmin's first-minimal-index
        # tie-break exactly. Cost-benefit keeps the vector scan: its
        # age-dependent score changes with every seal, so no incremental
        # order can be maintained.
        if self.greedy:
            fsx = self.fs
            self._vic_heap = [
                (int(v), b) for b, v in enumerate(fsx.blk_valid.tolist())
                if fsx.blk_state_mv[b] == 2]
            heapq.heapify(self._vic_heap)
        else:
            self._vic_heap = None
        # opt-in periodic in-run invariant checking: REPRO_CHECK_INVARIANTS=N
        # runs check_invariants every N GC cycles (N=1: every cycle), so a
        # long property sweep catches FTL corruption at the GC round that
        # introduced it instead of in a post-mortem assert at run end.
        try:
            self._check_every = int(
                os.environ.get("REPRO_CHECK_INVARIANTS", "0") or 0)
        except ValueError:
            self._check_every = 0

    # ---- physical service-path resolution ----
    def phys_loc(self, page: int) -> Tuple[int, int]:
        """(channel, die) of the page's current physical location —
        block-id-derived (``blk_loc``), consistent with where GC busy
        windows and frontier programs land. This is what every read and
        program queues on under ``ftl_backend="block"``; the legacy
        backend's logical hash lives in ``Channels.logical_loc``. With
        ``superblock`` the divisor is 1: the physical PAGE id routes, so
        a block's pages fan across channels then dies."""
        return blk_loc(self.fs.l2p_mv[page] // self.loc_div, self.n_channels)

    # ---- host program path (dirty evictions, compaction flush, Base
    # write-allocate fills) ----
    def on_flash_write(self, now: float, page: int) -> None:
        fs = self.fs
        s = self.s
        if s.ft_degraded:
            # spares exhausted: the device is read-only. The program is a
            # host-visible write error (counted), not an exception — reads
            # keep serving from the existing mapping.
            s.ft_write_errors += 1
            return
        ppb = fs.ppb
        l2p = fs.l2p_mv
        p2l = fs.p2l_mv
        pvalid = fs.pvalid_mv
        bvalid = fs.blk_valid_mv
        bstate = fs.blk_state_mv
        vh = self._vic_heap
        old = l2p[page]
        # rewrite heat must be read BEFORE the old copy is invalidated:
        # hot == the previous physical copy still sits in an open block
        # or one sealed within the heat window (the page's rewrite
        # interval is short relative to the data set) — unless that copy
        # was written by GC (blk_gc): a migrated page is a cold survivor
        # and GC's frontier recency says nothing about ITS rewrite rate
        ob = old // ppb
        hot = fs.hot_blk >= 0 and old >= 0 and not fs.blk_gc_mv[ob] and (
            bstate[ob] == 1
            or fs.seal_seq - fs.blk_seal_mv[ob] <= fs.heat_win)
        if hot and self._acc_mv is not None \
                and self._acc_mv[page] + 1 >= self._promo_thr:
            # one access from promotion: this copy's rewrite stream moves
            # to host DRAM, so it must not anchor a hot stripe (see
            # __init__'s _promo_gate rationale)
            hot = False
        b = fs.hot_blk if hot else fs.host_blk
        slot = fs.hot_slot if hot else fs.host_slot
        # charge the program at the destination's channel/die (same
        # bus->die recipe as Channels.write; blk_loc inlined). Superblock
        # routes the destination SLOT's stripe position, per-die blocks
        # route the block id — the same loc_div unification as phys_loc.
        n_ch = self.n_channels
        lb = b * ppb + slot if self.superblock else b
        ch = lb % n_ch
        d = (lb // n_ch) % DIES_PER_CHANNEL
        bus = s.chan_bus[ch]
        xfer_end = (now if now > bus else bus) + TRANSFER_NS
        s.chan_bus[ch] = xfer_end
        die = s.chan_die[ch]
        dv = die[d]
        die[d] = (xfer_end if xfer_end > dv else dv) + self.program_ns
        s.chan_busy_ns += TRANSFER_NS + self.program_ns / DIES_PER_CHANNEL
        s.flash_writes += 1
        o = s.obs
        if o is not None:
            o.on_program(now)
        if old >= 0:  # invalidate the stale physical copy
            pvalid[old] = False
            nv = bvalid[ob] - 1
            bvalid[ob] = nv
            p2l[old] = -1
            if vh is not None and bstate[ob] == 2:
                heapq.heappush(vh, (nv, ob))
        pp = b * ppb + slot
        # Install the mapping BEFORE any seal/GC: if this program fills
        # the frontier and every earlier slot was already invalidated
        # (rewrite-heavy locality), the just-sealed block would otherwise
        # count zero valid pages, get picked as the GC victim, and be
        # erased with the in-flight page's mapping still pending —
        # silently losing the write when the slot is reallocated.
        l2p[page] = pp
        p2l[pp] = page
        pvalid[pp] = True
        bvalid[b] += 1
        slot += 1
        if slot >= ppb:  # frontier sealed: GC if the pool runs low
            bstate[b] = 2
            fs.seal_seq += 1
            fs.blk_seal_mv[b] = fs.seal_seq
            if vh is not None:
                heapq.heappush(vh, (bvalid[b], b))
            if len(fs.free) <= fs.reserve:
                self._collect(now)
            nb = self._pop_free()
            if nb >= 0:
                fs.blk_state_mv[nb] = 1
                fs.blk_gc_mv[nb] = False  # host-written data
            # nb == -1: no spare to reopen the frontier — the device just
            # went degraded/read-only; the -1 frontier is never written
            # again (the guard at the top rejects all further programs)
            if hot:
                fs.hot_blk = nb
                fs.hot_slot = 0
            else:
                fs.host_blk = nb
                fs.host_slot = 0
        elif hot:
            fs.hot_slot = slot
        else:
            fs.host_slot = slot

    def _pop_free(self) -> int:
        """Take a block from the free pool; returns -1 and flips the
        device into degraded read-only mode when the pool is exhausted
        (die failures ate the over-provisioning, or a degenerate geometry
        where every sealed block is fully valid and GC cannot free net
        space). This used to raise RuntimeError; a real device fails the
        WRITE path, not the whole machine — callers treat -1 as "no
        frontier" and on_flash_write starts counting host-visible write
        errors (Stats.degraded_mode / degraded_writes). With
        ``wear_leveling`` the pick is the lowest-erase-count free block
        (block-id tie-break, so the choice is independent of the pool's
        internal order) instead of the LIFO pop that recycles
        recently-erased blocks."""
        fs = self.fs
        free = fs.free
        if not free:
            self.s.ft_degraded = 1
            return -1
        if not self.wear_level:
            return free.pop()
        er = fs.blk_erase_mv
        best_i = 0
        best_b = free[0]
        best_e = er[best_b]
        for i in range(1, len(free)):
            b = free[i]
            e = er[b]
            if e < best_e or (e == best_e and b < best_b):
                best_i = i
                best_b = b
                best_e = e
        free[best_i] = free[-1]  # O(1) swap-remove; order-independent pick
        free.pop()
        return best_b

    # ---- garbage collection ----
    def _collect(self, now: float) -> None:
        fs = self.fs
        step = self._gc_once_super if self.superblock else self._gc_once
        guard = fs.n_blocks  # each round erases one block; hard bound
        while len(fs.free) <= fs.reserve and guard > 0:
            guard -= 1
            if not step(now):
                break

    def _pick_victim(self) -> int:
        """Deterministic victim among sealed blocks (-1 if none)."""
        fs = self.fs
        vh = self._vic_heap
        if vh is not None:  # greedy: lazy heap, see __init__
            bstate = fs.blk_state_mv
            bvalid = fs.blk_valid_mv
            while vh:
                v, b = vh[0]
                if bstate[b] == 2 and bvalid[b] == v:
                    # entry stays at the top: it is invalidated by the
                    # erase (state 0) or superseded by a smaller count,
                    # and discarded on a later pass either way
                    return b
                heapq.heappop(vh)
            return -1
        sealed = fs.blk_state == 2
        if not sealed.any():
            return -1
        # cost-benefit: (1 - u) / (1 + u) * age, u = valid/ppb, age in
        # seal-sequence ticks; first-maximal block index on ties
        v = fs.blk_valid.astype(np.float64)
        age = (fs.seal_seq - fs.blk_seal).astype(np.float64)
        score = np.where(sealed, (fs.ppb - v) / (fs.ppb + v) * age, -1.0)
        return int(score.argmax())

    def _gc_once(self, now: float) -> bool:
        fs = self.fs
        s = self.s
        if s.ft_degraded or fs.gc_blk < 0:
            return False  # read-only: no frontier to migrate into
        b = self._pick_victim()
        if b < 0:
            return False
        ppb = fs.ppb
        if fs.blk_valid_mv[b] >= ppb and not fs.free:
            return False  # fully-valid victim cannot free net space
        base = b * ppb
        live = np.flatnonzero(fs.pvalid[base:base + ppb])
        n_live = int(live.size)
        # victim die: erase + one read per live page; bus: the read-out
        # transfers. Proportional to migration work, so coalesced logs
        # (fewer live pages per victim) see measurably shorter windows.
        # The carved window is recorded ([gc_die_from, gc_die_until],
        # contiguous windows merged): reads whose wait overlaps it are
        # attributed as GC pauses.
        ch, d = blk_loc(b, self.n_channels)
        die = s.chan_die[ch]
        dv = die[d]
        start = now if now > dv else dv
        die[d] = start + self.erase_ns + n_live * self.read_ns
        if start > s.gc_die_until[ch][d]:
            # new window (vs merging into a live one): refill the die's
            # bounded suspend budget and count it for the suspends-per-
            # window QoS bound
            s.gc_die_from[ch][d] = start
            s.gc_susp_left[ch][d] = self.susp_max
            s.gc_windows += 1
        s.gc_die_until[ch][d] = die[d]
        o = s.obs
        if o is not None:  # victim erase + read-out slice
            o.on_gc_window(ch, d, start, die[d])
        bus = s.chan_bus[ch]
        s.chan_bus[ch] = (now if now > bus else bus) \
            + n_live * TRANSFER_NS
        s.chan_busy_ns += self.erase_ns / DIES_PER_CHANNEL + n_live * (
            TRANSFER_NS + self.read_ns / DIES_PER_CHANNEL)
        # migrate live pages to the GC frontier. Each page's program cost
        # (bus transfer -> die program, GC-window merge) must stay a
        # sequential float chain for bit-exactness, so the per-page body
        # is scalar — but frontier state, mapping memoryviews, and the
        # block's (channel, die) resolution are hoisted per frontier
        # SEGMENT (the run of pages landing in one GC block). Seal/pop
        # bookkeeping moves to the segment end: nothing between a
        # segment's programs reads blk_state/free/blk_erase, and a page's
        # timing is charged at the block it landed in either way, so the
        # final state is identical to the old per-page _alloc_gc calls.
        if n_live:
            program_ns = self.program_ns
            busy_inc = TRANSFER_NS + program_ns / DIES_PER_CHANNEL
            l2p = fs.l2p_mv
            p2l = fs.p2l_mv
            pvalid = fs.pvalid_mv
            chan_bus = s.chan_bus
            gdf = s.gc_die_from
            gdu = s.gc_die_until
            busy = s.chan_busy_ns
            inv_np = base + live
            lps_np = fs.p2l[inv_np]
            lps = offs = None  # listed lazily: only short segments need it
            n_ch = self.n_channels
            vh = self._vic_heap
            heappush = heapq.heappush
            arange = np.arange
            susp_left = s.gc_susp_left
            susp_max = self.susp_max
            x = 0
            while x < n_live:
                b2 = fs.gc_blk
                slot = fs.gc_slot
                seg = ppb - slot
                if seg > n_live - x:
                    seg = n_live - x
                ch2 = b2 % n_ch
                d2 = (b2 // n_ch) % DIES_PER_CHANNEL
                die2 = s.chan_die[ch2]
                gu_row = gdu[ch2]
                bus2 = chan_bus[ch2]
                dv2 = die2[d2]
                gu = gu_row[d2]
                gf = gdf[ch2][d2]
                pp0 = b2 * ppb + slot
                # first page: full recipe (bus/die frontiers may lag now)
                bus2 = (now if now > bus2 else bus2) + TRANSFER_NS
                st2 = now if now > dv2 else dv2
                dv2 = st2 + program_ns
                # migration programs are GC work: extend/merge the window
                if st2 > gu:
                    gf = st2
                    susp_left[ch2][d2] = susp_max
                    s.gc_windows += 1
                busy += busy_inc
                # pages 2..seg: after the first program, bus2 and dv2 sit
                # strictly past `now` and each page's start time equals
                # the previous page's die frontier (st2 == dv2 == gu), so
                # the max() comparisons and the window-from update are
                # provable no-ops — the chain degenerates, bit-exactly,
                # to three sequential float adds per page.
                for _ in range(seg - 1):
                    bus2 += TRANSFER_NS
                    dv2 += program_ns
                    busy += busy_inc
                gu = dv2
                # mapping scatter: nothing between a segment's programs
                # reads the mapping, and source (victim b) / destination
                # (frontier b2) slots are disjoint, so the per-page
                # interleave can collapse to bulk array ops; below the
                # dispatch break-even the scalar loop stays cheaper
                if seg >= 24:
                    seg_lps = lps_np[x:x + seg]
                    fs.l2p[seg_lps] = arange(pp0, pp0 + seg)
                    fs.p2l[pp0:pp0 + seg] = seg_lps
                    fs.pvalid[pp0:pp0 + seg] = True
                    fs.p2l[inv_np[x:x + seg]] = -1
                else:
                    if lps is None:
                        lps = lps_np.tolist()
                        offs = live.tolist()
                    pp_new = pp0
                    for i in range(x, x + seg):
                        lp = lps[i]
                        l2p[lp] = pp_new
                        p2l[pp_new] = lp
                        pvalid[pp_new] = True
                        p2l[base + offs[i]] = -1
                        pp_new += 1
                chan_bus[ch2] = bus2
                die2[d2] = dv2
                gu_row[d2] = gu
                gdf[ch2][d2] = gf
                if o is not None:  # migration-program slice (one segment)
                    o.on_gc_window(ch2, d2, st2, dv2)
                fs.blk_valid_mv[b2] += seg
                x += seg
                slot += seg
                if slot >= ppb:  # GC frontier sealed: open a fresh block
                    fs.blk_state_mv[b2] = 2
                    fs.seal_seq += 1
                    fs.blk_seal_mv[b2] = fs.seal_seq
                    if vh is not None:
                        heappush(vh, (fs.blk_valid_mv[b2], b2))
                    nb = self._pop_free()
                    if nb < 0:
                        # spares exhausted MID-migration (now degraded):
                        # abort. The migrated prefix's source slots are
                        # normally invalidated wholesale by the erase
                        # below, which can no longer happen — fix them up
                        # here so the mapping invariants hold, and leave
                        # the victim sealed with its unmigrated tail.
                        fs.gc_blk = -1
                        fs.gc_slot = 0
                        s.chan_busy_ns = busy
                        fs.pvalid[inv_np[:x]] = False
                        fs.blk_valid_mv[b] = fs.blk_valid_mv[b] - x
                        if vh is not None:
                            heappush(vh, (fs.blk_valid_mv[b], b))
                        s.gc_migrated_pages += x
                        if o is not None:
                            o.on_gc_migrated(now, x)
                        return False
                    fs.blk_state_mv[nb] = 1
                    fs.blk_gc_mv[nb] = True  # GC-written data: never "hot"
                    fs.gc_blk = nb
                    fs.gc_slot = 0
                else:
                    fs.gc_slot = slot
            s.chan_busy_ns = busy
        s.gc_migrated_pages += n_live
        if o is not None:
            o.on_gc_migrated(now, n_live)
        # erase the victim back into the pool
        fs.pvalid[base:base + ppb] = False
        fs.blk_valid_mv[b] = 0
        fs.blk_erase_mv[b] += 1
        fs.blk_state_mv[b] = 0
        fs.free.append(b)
        s.gc_events += 1
        if self._check_every and s.gc_events % self._check_every == 0:
            check_invariants(fs, degraded=bool(s.ft_degraded))
        return True

    def _gc_once_super(self, now: float) -> bool:
        """One GC round under superblock striping. Identical mapping
        outcome to ``_gc_once`` (victim pick, migration order, seal/pop
        bookkeeping, degraded abort are byte-for-byte the same state
        machine), but the PHYSICAL footprint inverts: the victim's pages
        live on up to min(ppb, n_channels * DIES_PER_CHANNEL) distinct
        dies, so the erase + read-out carves a SHALLOW window
        (erase_ns + per-die-live * read_ns) on EVERY die the stripe
        touches instead of one deep window on one die — stripe-parallel
        reads buy bandwidth, GC buys blast radius. Each migrated page is
        likewise programmed at its own stripe position's die, so the
        degenerate same-die float chain that lets ``_gc_once`` collapse a
        segment to three adds never forms; the per-page loop runs the
        full bus->die recipe (scalar, bit-exact by construction since
        both engines call this one method)."""
        fs = self.fs
        s = self.s
        if s.ft_degraded or fs.gc_blk < 0:
            return False  # read-only: no frontier to migrate into
        b = self._pick_victim()
        if b < 0:
            return False
        ppb = fs.ppb
        if fs.blk_valid_mv[b] >= ppb and not fs.free:
            return False  # fully-valid victim cannot free net space
        base = b * ppb
        live = np.flatnonzero(fs.pvalid[base:base + ppb])
        n_live = int(live.size)
        n_ch = self.n_channels
        read_ns = self.read_ns
        erase_ns = self.erase_ns
        susp_max = self.susp_max
        # --- erase + read-out, grouped per die the stripe touches. The
        # erase must hit every die holding a slice (all ppb slots, live
        # or not); the read-outs only the live ones. Slice order (pp
        # ascending) fixes the iteration order deterministically. ---
        die_live = {}
        for off in range(ppb):
            pp = base + off
            loc = (pp % n_ch, (pp // n_ch) % DIES_PER_CHANNEL)
            if loc not in die_live:
                die_live[loc] = 0
        chan_xfer = {}
        for off in live.tolist():
            pp = base + off
            loc = (pp % n_ch, (pp // n_ch) % DIES_PER_CHANNEL)
            die_live[loc] += 1
            chan_xfer[loc[0]] = chan_xfer.get(loc[0], 0) + 1
        o = s.obs
        for (ch, d), nl in die_live.items():
            die = s.chan_die[ch]
            dv = die[d]
            start = now if now > dv else dv
            die[d] = start + erase_ns + nl * read_ns
            if start > s.gc_die_until[ch][d]:
                s.gc_die_from[ch][d] = start
                s.gc_susp_left[ch][d] = susp_max
                s.gc_windows += 1
            s.gc_die_until[ch][d] = die[d]
            if o is not None:  # shallow per-die erase/read-out slice
                o.on_gc_window(ch, d, start, die[d])
            s.chan_busy_ns += erase_ns / DIES_PER_CHANNEL \
                + nl * (read_ns / DIES_PER_CHANNEL)
        for ch, nx in chan_xfer.items():
            bus = s.chan_bus[ch]
            s.chan_bus[ch] = (now if now > bus else bus) + nx * TRANSFER_NS
            s.chan_busy_ns += nx * TRANSFER_NS
        # --- migrate live pages to the GC frontier, one stripe position
        # (hence usually one distinct die) per page ---
        if n_live:
            program_ns = self.program_ns
            busy_inc = TRANSFER_NS + program_ns / DIES_PER_CHANNEL
            l2p = fs.l2p_mv
            p2l = fs.p2l_mv
            pvalid = fs.pvalid_mv
            bvalid = fs.blk_valid_mv
            vh = self._vic_heap
            heappush = heapq.heappush
            offs = live.tolist()
            x = 0
            for off in offs:
                src = base + off
                lp = p2l[src]
                b2 = fs.gc_blk
                slot = fs.gc_slot
                pp2 = b2 * ppb + slot
                ch2 = pp2 % n_ch
                d2 = (pp2 // n_ch) % DIES_PER_CHANNEL
                bus2 = s.chan_bus[ch2]
                s.chan_bus[ch2] = (now if now > bus2 else bus2) + TRANSFER_NS
                die2 = s.chan_die[ch2]
                dv2 = die2[d2]
                st2 = now if now > dv2 else dv2
                dv2 = st2 + program_ns
                die2[d2] = dv2
                if st2 > s.gc_die_until[ch2][d2]:
                    s.gc_die_from[ch2][d2] = st2
                    s.gc_susp_left[ch2][d2] = susp_max
                    s.gc_windows += 1
                s.gc_die_until[ch2][d2] = dv2
                if o is not None:  # per-page stripe program: too fine
                    o.on_gc_busy(st2, dv2 - st2)  # for the event ring
                s.chan_busy_ns += busy_inc
                l2p[lp] = pp2
                p2l[pp2] = lp
                pvalid[pp2] = True
                p2l[src] = -1
                bvalid[b2] += 1
                x += 1
                slot += 1
                if slot >= ppb:  # GC frontier sealed: open a fresh block
                    fs.blk_state_mv[b2] = 2
                    fs.seal_seq += 1
                    fs.blk_seal_mv[b2] = fs.seal_seq
                    if vh is not None:
                        heappush(vh, (bvalid[b2], b2))
                    nb = self._pop_free()
                    if nb < 0:
                        # spares exhausted mid-migration: same abort
                        # fixup as _gc_once — invalidate the migrated
                        # prefix's source slots (the erase below cannot
                        # happen) and leave the victim sealed.
                        fs.gc_blk = -1
                        fs.gc_slot = 0
                        fs.pvalid[base + live[:x]] = False
                        bvalid[b] = bvalid[b] - x
                        if vh is not None:
                            heappush(vh, (bvalid[b], b))
                        s.gc_migrated_pages += x
                        if o is not None:
                            o.on_gc_migrated(now, x)
                        return False
                    fs.blk_state_mv[nb] = 1
                    fs.blk_gc_mv[nb] = True  # GC-written data: never "hot"
                    fs.gc_blk = nb
                    fs.gc_slot = 0
                else:
                    fs.gc_slot = slot
        s.gc_migrated_pages += n_live
        if o is not None:
            o.on_gc_migrated(now, n_live)
        # erase the victim back into the pool
        fs.pvalid[base:base + ppb] = False
        fs.blk_valid_mv[b] = 0
        fs.blk_erase_mv[b] += 1
        fs.blk_state_mv[b] = 0
        fs.free.append(b)
        s.gc_events += 1
        if self._check_every and s.gc_events % self._check_every == 0:
            check_invariants(fs, degraded=bool(s.ft_degraded))
        return True

    # ---- whole-die hard failure (core/faults.py schedules these) ----
    def fail_die(self, now: float, ch: int, d: int) -> None:
        """Permanently fail every block on physical die ``(ch, d)``:
        prune them from the free pool, mark them bad (state 3 — never
        erased, never victimized: the lazy heap and the cost-benefit scan
        both only accept state 2), reopen any write frontier that lived
        on the die, and remap the surviving valid pages out through the
        ordinary program path, so heat classification, frontier seals and
        GC pressure all behave exactly as for host writes. If the
        remaining spares cannot absorb the remap the device goes degraded
        mid-way: the unmigrated pages stay mapped to bad blocks (reads
        still route there — the latency model doesn't care that the data
        is fiction, and check_invariants permits it while degraded)."""
        fs = self.fs
        s = self.s
        n_ch = self.n_channels
        stride = n_ch * DIES_PER_CHANNEL
        bad = [b for b in range(ch + n_ch * d, fs.n_blocks, stride)
               if fs.blk_state_mv[b] != 3]
        if not bad:
            return  # this die already failed
        s.ft_die_failures += 1
        s.ft_bad_blocks += len(bad)
        bad_set = set(bad)
        fs.free[:] = [blk for blk in fs.free if blk not in bad_set]
        for blk in bad:
            fs.blk_state_mv[blk] = 3
        for kind in ("host", "hot", "gc"):
            blk = getattr(fs, kind + "_blk")
            if blk >= 0 and blk in bad_set:
                nb = self._pop_free()
                if nb >= 0:
                    fs.blk_state_mv[nb] = 1
                    fs.blk_gc_mv[nb] = kind == "gc"
                setattr(fs, kind + "_blk", nb)
                setattr(fs, kind + "_slot", 0)
        ppb = fs.ppb
        p2l = fs.p2l_mv
        pvalid = fs.pvalid_mv
        for blk in bad:
            base = blk * ppb
            for pp in range(base, base + ppb):
                if pvalid[pp] and not s.ft_degraded:
                    lp = p2l[pp]
                    if lp >= 0:
                        # invalidates pp via the stale-copy path (bad
                        # blocks are state 3, so no victim-heap push)
                        self.on_flash_write(now, lp)
                        s.ft_remapped_pages += 1


def check_invariants(fs: FlashState, degraded: bool = False) -> None:
    """Assert the valid-count / bitmap / mapping invariants. Test hook,
    and — with REPRO_CHECK_INVARIANTS=N — a periodic in-run checker
    (every N GC cycles). ``degraded`` relaxes what a read-only device
    cannot uphold: frontiers may be -1 and bad blocks may still hold
    valid pages whose remap was cut short."""
    ppb = fs.ppb
    per_block = fs.pvalid.reshape(fs.n_blocks, ppb).sum(axis=1)
    assert (per_block == fs.blk_valid).all(), "blk_valid != bitmap sums"
    mapped = np.flatnonzero(fs.l2p >= 0)
    assert int(fs.blk_valid.sum()) == mapped.size, "valid total != mapped"
    pp = fs.l2p[mapped]
    assert fs.pvalid[pp].all(), "mapped physical slots must be valid"
    assert (fs.p2l[pp] == mapped).all(), "l2p/p2l must be inverse"
    free_set = set(fs.free)
    assert len(free_set) == len(fs.free), "duplicate blocks in free pool"
    for b in range(fs.n_blocks):
        st = int(fs.blk_state[b])
        assert (b in free_set) == (st == 0), "free pool vs blk_state drift"
        if st == 0:
            assert int(fs.blk_valid[b]) == 0, "free block holds valid pages"
        if st == 3 and not degraded:
            assert int(fs.blk_valid[b]) == 0, \
                "bad block still holds valid pages on a healthy device"
    if degraded:
        for blk in (fs.host_blk, fs.gc_blk, fs.hot_blk):
            assert blk < 0 or fs.blk_state[blk] == 1, \
                "a surviving frontier must stay open"
        return
    assert fs.blk_state[fs.host_blk] == 1 and fs.blk_state[fs.gc_blk] == 1
    assert fs.blk_gc[fs.gc_blk] and not fs.blk_gc[fs.host_blk]
    if fs.hot_blk >= 0:
        assert fs.blk_state[fs.hot_blk] == 1, "hot frontier must stay open"
        assert len({fs.host_blk, fs.gc_blk, fs.hot_blk}) == 3, \
            "frontiers must be distinct blocks"
