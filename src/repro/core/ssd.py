"""CXL-SSD device policies: write log, data cache, FTL channels, GC.

Faithful to the paper's §III-B / Table II structures at request-event
granularity. Since the unified-state refactor, the classes here are thin
*policy/view* layers over one authoritative ``DeviceState``
(``device_state.py``): they own behaviour (lookup/insert/evict/compact
rules, Algorithm 1's latency estimator), while every piece of mutable
state — membership arrays, LRU stamps, log buffers and line bitmasks,
channel/die busy timelines, free-page accounting — lives in the shared
structure-of-arrays object both replay engines operate on.

  * ``WriteLog`` — double-buffered cacheline-granular circular log with a
    two-level index (page -> {line -> newest}) plus a per-page 64-bit
    line-presence bitmask (the batched engine's classification input).
    Lookup *latency* is charged from the §V FPGA measurements (72 ns log
    index, 49 ns cache index), so the host-visible timing matches the
    prototype, not Python.
  * ``DataCache`` — set-associative, page-granular, LRU, write-back. LRU
    recency is a monotone int64 stamp per page (fresh stamp per
    touch/insert == OrderedDict move-to-end order, bit-for-bit); the
    victim of a full set is its min-stamp slot. Stamps make a bulk LRU
    touch a single NumPy scatter for the batched engine.
  * ``Channels`` — per-channel bus + per-die busy timelines; Algorithm 1's
    latency estimator is literally ``max(0, busy_until - now) + t_read``.
  * GC — free-page accounting; when utilization crosses the threshold a
    channel is occupied for an erase + valid-page migration window, and
    every request routed to it sees the delay through the estimator
    (exactly how the paper's trigger policy observes GC).

Capacities honor SimConfig.scale (ratios fixed, absolute sizes scaled).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL, DeviceState

TRANSFER_NS = 800.0  # 4KB page over the channel bus (~5 GB/s ONFI bus)


class Channels:
    """Flash timing policy over the shared bus/die timelines.

    Table II's geometry (16 channels x 8 chips x 8 dies = 1024 dies) means
    tProg/tR occupy a *die* while the channel bus is only held for the 4KB
    transfer — programs overlap massively across dies (this is what makes
    write-back SSDs viable at all). Algorithm 1's estimator reads this
    queue state exactly as the paper's FTL does.

    Since the physical-routing refactor every timing method takes an
    already-resolved ``(ch, d)`` location: under the block FTL that is
    ``BlockFtl.phys_loc(page)`` (the die the FTL actually placed the page
    on); under the legacy backend it is ``logical_loc(page)`` — the ONE
    remaining copy of the historical page-hash stripe.
    """

    def __init__(self, cfg: SimConfig, state: DeviceState):
        self.cfg = cfg
        self.s = state
        self.n_channels = cfg.n_channels
        self.read_ns = cfg.flash.read_ns
        self.program_ns = cfg.flash.program_ns
        # fault injector (core/faults.py); attached by Machine.__init__
        # when any FaultConfig knob is nonzero, else stays None and read()
        # pays one is-not-None test
        self.fault = None
        # die-level QoS arbiter (core/qos.py); attached by
        # Machine.__init__ when cfg.qos_enabled — same conflict-class
        # contract and same is-not-None cost as the fault injector.
        # Config validation forbids fault+QoS together, so at most one
        # dispatch fires per read.
        self.qos = None
        # latency-provenance recorder (core/obs.py); attached by
        # Machine.__init__ when cfg.obs.enabled. Unlike fault/qos it
        # COMPOSES with either: capture happens inside each read method
        # behind its own is-not-None test, not via the dispatch slots.
        self.obs = None
        # per-window suspend budget refill (see DeviceState.gc_susp_left);
        # cached here for the legacy gc() carve below
        self.gc_susp_max = cfg.gc_suspend_max

    def logical_loc(self, page: int) -> Tuple[int, int]:
        """Legacy page-interleaved striping: (channel, die) from the
        LOGICAL page id. The PR 4-era hash, kept bit-exact as the
        ``ftl_backend="legacy"`` service path and regression anchor."""
        return ((page * 1103515245 + 12345) % self.n_channels,
                (page // self.n_channels) % DIES_PER_CHANNEL)

    def estimate(self, ch: int, d: int, now: float) -> float:
        """Algorithm 1: queued delay + read latency for this die/bus."""
        s = self.s
        wait = max(s.chan_die[ch][d] - now, s.chan_bus[ch] - now, 0.0)
        return wait + self.read_ns

    def read(self, ch: int, d: int, now: float,
             gc_attr: bool = True) -> float:
        """Issue a flash page read; returns data-available time. The part
        of the read's die wait that overlaps the last GC-carved window
        ([gc_die_from, gc_die_until]) is attributed as a host-observed GC
        pause — the accounting the fig14 exec-time story reads (mirrored
        verbatim by the batched engine's inline-span read sites). Clipping
        at the window START keeps wait the read would have suffered behind
        ordinary host programs out of the GC books. ``gc_attr=False``
        marks a device-internal read no thread blocks on (compaction
        coalescing-buffer fills, Base-CSSD write-allocate background
        fetches): it still occupies the die/bus but books no pause."""
        f = self.fault
        if f is not None:  # retry ladder / outages / scheduled events
            return f.read(ch, d, now, gc_attr)
        q = self.qos
        if q is not None:  # GC suspend/resume + read-priority arbitration
            return q.read(ch, d, now, gc_attr)
        s = self.s
        die = s.chan_die[ch]
        dv = die[d]
        o = self.obs
        pause = 0.0
        if gc_attr and dv > now:
            gu = s.gc_die_until[ch][d]
            if gu > now:
                gf = s.gc_die_from[ch][d]
                lo = now if now > gf else gf
                hi = dv if dv < gu else gu
                pause = hi - lo
                if pause > 0.0:
                    s.gc_stall_events += 1
                    s.gc_pause_ns_total += pause
                    if o is not None:
                        o.gc_pause_site += pause  # bit-exact mirror
                    if pause > s.gc_pause_max_ns:
                        s.gc_pause_max_ns = pause
                else:
                    pause = 0.0
        start = now if now > dv else dv
        sensed = start + self.read_ns
        xfer_start = max(sensed, s.chan_bus[ch])
        done = xfer_start + TRANSFER_NS
        die[d] = sensed
        s.chan_bus[ch] = done
        s.chan_busy_ns += TRANSFER_NS + self.read_ns / DIES_PER_CHANNEL
        s.flash_reads += 1
        if o is not None and gc_attr:
            die_wait = start - now
            queue = die_wait - pause
            if queue < 0.0:
                queue = 0.0
            o.stage_read(ch, d, now, die_wait, queue, pause, 0.0, 0.0,
                         0.0, self.read_ns, 0.0, xfer_start - sensed,
                         TRANSFER_NS, done)
        return done

    def write(self, ch: int, d: int, now: float) -> float:
        """Issue a flash program; bus for the transfer, die for tProg."""
        s = self.s
        die = s.chan_die[ch]
        xfer_start = max(now, s.chan_bus[ch])
        s.chan_bus[ch] = xfer_start + TRANSFER_NS
        start = max(xfer_start + TRANSFER_NS, die[d])
        done = start + self.program_ns
        die[d] = done
        s.chan_busy_ns += TRANSFER_NS + self.program_ns / DIES_PER_CHANNEL
        s.flash_writes += 1
        o = self.obs
        if o is not None:
            o.on_program(now)
        return done

    def gc(self, now: float) -> None:
        """Occupy one die with erase + valid-page migration (plus bus time
        for the migrated pages). Channel and die advance on decorrelated
        strides: the historical ``gc_events % DIES_PER_CHANNEL`` die pick
        moved in lockstep with the channel pick, so only the 64 diagonal
        (ch, die) pairs out of 1024 ever absorbed GC work."""
        cfg = self.cfg
        s = self.s
        ch = s.gc_events % cfg.n_channels
        d = (s.gc_events // cfg.n_channels) % DIES_PER_CHANNEL
        cost = cfg.flash.erase_ns + 8 * (cfg.flash.read_ns + cfg.flash.program_ns)
        start = max(now, s.chan_die[ch][d])
        s.chan_die[ch][d] = start + cost
        # GC-pause window: merge with the previous one when contiguous; a
        # NEW window refills the die's bounded suspend budget
        if start > s.gc_die_until[ch][d]:
            s.gc_die_from[ch][d] = start
            s.gc_susp_left[ch][d] = self.gc_susp_max
            s.gc_windows += 1
        s.gc_die_until[ch][d] = s.chan_die[ch][d]
        s.chan_bus[ch] = max(now, s.chan_bus[ch]) + 8 * TRANSFER_NS
        s.chan_busy_ns += cost / DIES_PER_CHANNEL
        s.gc_events += 1
        s.gc_migrated_pages += 8  # the fixed migration the cost models
        o = self.obs
        if o is not None:
            o.on_gc_window(ch, d, start, s.chan_die[ch][d])
            o.on_gc_migrated(now, 8)


class Ftl:
    """Legacy free-page accounting driving the GC model
    (``SimConfig.ftl_backend = "legacy"``; the default block-granular
    backend lives in ``core/flash.py`` and shares this interface).

    Like the block FTL, ``on_flash_write`` performs the whole program:
    it charges the bus/die timing (at the LOGICAL hash stripe — the PR 4
    behaviour, bit-for-bit: write first, then the free-page counter and
    its threshold GC, the exact operation order the old caller-side
    ``channels.write`` + ``on_flash_write`` pair produced) and then the
    accounting."""

    def __init__(self, cfg: SimConfig, state: DeviceState, channels: Channels):
        self.cfg = cfg
        self.s = state
        self.channels = channels

    def on_flash_write(self, now: float, page: int) -> None:
        # page is required (matches BlockFtl): it determines the charged
        # (channel, die) — a defaulted -1 would silently stripe to a
        # fixed bogus location
        ch = self.channels
        ch.write(*ch.logical_loc(page), now)
        s = self.s
        s.ftl_used += 1  # out-of-place update consumes a free page
        if s.ftl_used >= s.ftl_total:
            ch.gc(now)
            s.ftl_used -= max(
                int(s.ftl_total * (1.0 - self.cfg.gc_threshold)), 1)


class WriteLog:
    """Double-buffered cacheline write log with two-level indexing.

    State (active/old dicts, fill level, per-page line bitmask) lives on
    DeviceState. Appends maintain the bitmask but do NOT bump page epochs
    (line presence only grows between compactions; the batched engine
    absorbs new lines through its log overlay). Compaction breaks the
    monotonicity — lines vanish all at once — so the swap bumps every page
    the drained buffer held."""

    def __init__(self, cfg: SimConfig, state: DeviceState):
        self.cfg = cfg
        self.s = state
        self.cap = state.log_cap

    def lookup(self, page: int, line: int) -> bool:
        s = self.s
        e = s.log_active.get(page)
        if e is not None and line in e:
            return True
        e = s.log_old.get(page)
        return e is not None and line in e

    def append(self, page: int, line: int) -> bool:
        """Returns True if this append filled the active log (compaction)."""
        s = self.s
        e = s.log_active.get(page)
        if e is None:
            e = s.log_active[page] = {}
        if line not in e:
            e[line] = True
            s.log_bits[page] |= np.uint64(1 << line)
            s.log_active_n += 1
        return s.log_active_n >= self.cap

    def bulk_append_new(self, pages, lines) -> None:
        """Append a batch of (page, line) entries in order (page insertion
        order is observable at compaction time through the channel
        timeline). Entries already present are skipped exactly as append()
        would — callers may pass writes whose pair arrived since they were
        classified. Used by the batched engine; the batch is bounded so the
        log can never fill mid-batch (the engine's fill prediction counts
        candidate-new pairs, an overestimate of the true fill level)."""
        s = self.s
        # bitwise_or.at: pages may repeat within a batch (several new lines
        # of one page); plain fancy-index |= would drop all but one OR.
        # Setting bits for pairs the dup-tolerant scalar path then skips is
        # harmless — they are already present by definition.
        np.bitwise_or.at(s.log_bits, pages,
                         np.uint64(1) << lines.astype(np.uint64))
        act = s.log_active
        n = s.log_active_n
        for p, l in zip(pages.tolist(), lines.tolist()):
            e = act.get(p)
            if e is None:
                act[p] = {l: True}
                n += 1
            elif l not in e:
                e[l] = True
                n += 1
        s.log_active_n = n

    def swap_for_compaction(self):
        s = self.s
        s.log_bits[:] = 0
        old = s.log_active
        if old:
            s.bump_list(list(old))
        s.log_old = old
        s.log_active = {}
        s.log_active_n = 0
        s.log_compactions += 1
        return old

    def finish_compaction(self) -> None:
        self.s.log_old = {}

    # observability passthroughs (BENCH / simulate tail)
    @property
    def compactions(self) -> int:
        return self.s.log_compactions

    @property
    def flushed_pages(self) -> int:
        return self.s.log_flushed_pages

    @property
    def flushed_lines(self) -> int:
        return self.s.log_flushed_lines


class DataCache:
    """Set-associative page-granular LRU write-back cache over the shared
    stamp/membership arrays.

    Exact-equivalence contract with the OrderedDict implementation it
    replaced: every touch or insert assigns a fresh monotone stamp
    (``state.cache_clock``), so "least recently used" == "smallest stamp",
    ties are impossible, and eviction picks the same victim the ordered
    dict's popitem(last=False) would."""

    def __init__(self, cfg: SimConfig, state: DeviceState):
        self.cfg = cfg
        self.s = state
        self.ways = state.cache_ways
        self.n_sets = state.cache_n_sets

    def lookup(self, page: int, touch: bool = True) -> Optional[bool]:
        """Returns dirty-bit if present else None."""
        s = self.s
        if not s.cache_res_mv[page]:
            return None
        if touch:
            c = s.cache_clock + 1
            s.cache_clock = c
            s.cache_stamp_mv[page] = c
        return s.cache_dirty_mv[page]

    def insert(self, page: int, dirty: bool) -> Optional[Tuple[int, bool]]:
        """Insert/overwrite; returns evicted (page, dirty) if any."""
        s = self.s
        if s.cache_res_mv[page]:
            if dirty:
                s.cache_dirty_mv[page] = True
            c = s.cache_clock + 1
            s.cache_clock = c
            s.cache_stamp_mv[page] = c
            return None
        row = s.cache_sets[page % self.n_sets]
        stamp = s.cache_stamp_mv
        victim_w = 0
        victim_p = -1
        victim_stamp = None
        for w, q in enumerate(row):
            if q < 0:  # free slot: no eviction needed
                victim_w = w
                victim_p = -1
                break
            sq = stamp[q]
            if victim_stamp is None or sq < victim_stamp:
                victim_stamp = sq
                victim_w = w
                victim_p = q
        evicted = None
        if victim_p >= 0:
            evicted = (victim_p, s.cache_dirty_mv[victim_p])
            s.cache_res_mv[victim_p] = False
            s.cache_way[victim_p] = -1
            s.bump(victim_p)
        row[victim_w] = page
        s.cache_way[page] = victim_w
        s.cache_res_mv[page] = True
        s.cache_dirty_mv[page] = dirty
        c = s.cache_clock + 1
        s.cache_clock = c
        s.cache_stamp_mv[page] = c
        s.bump(page)
        return evicted

    def mark_dirty(self, page: int) -> None:
        s = self.s
        if s.cache_res_mv[page]:
            s.cache_dirty_mv[page] = True

    def bulk_touch(self, pages) -> None:
        """Refresh LRU recency for a batch of resident-page touch events in
        event order — ONE scatter. Duplicate pages resolve to their last
        occurrence (scatter keeps the last write), and the clock advances
        by the event count, so the stamps are identical to the per-event
        scalar path's."""
        k = pages.shape[0]
        if not k:
            return
        s = self.s
        c = s.cache_clock
        s.cache_stamp[pages] = np.arange(c + 1, c + k + 1)
        s.cache_clock = c + k

    def remove(self, page: int) -> None:
        s = self.s
        if s.cache_res_mv[page]:
            s.cache_sets[page % self.n_sets][s.cache_way[page]] = -1
            s.cache_way[page] = -1
            s.cache_res_mv[page] = False
            s.bump(page)
