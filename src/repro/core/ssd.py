"""CXL-SSD device model: write log, data cache, FTL channels, GC.

Faithful to the paper's §III-B / Table II structures at request-event
granularity:

  * ``WriteLog`` — double-buffered cacheline-granular circular log with a
    two-level index (page -> {line -> newest}). Python dicts give the same
    amortized O(1) lookup the paper's two-level hash tables give in
    hardware; lookup *latency* is charged from the §V FPGA measurements
    (72 ns log index, 49 ns cache index), so the host-visible timing — the
    thing the simulator measures — matches the prototype, not Python.
  * ``DataCache`` — set-associative, page-granular, LRU, write-back.
  * ``Channels`` — per-channel FIFO busy-until timeline; Algorithm 1's
    latency estimator is literally ``max(0, busy_until - now) + t_read``.
  * GC — free-page accounting; when utilization crosses the threshold a
    channel is occupied for an erase + valid-page migration window, and
    every request routed to it sees the delay through the estimator
    (exactly how the paper's trigger policy observes GC).

Capacities honor SimConfig.scale (ratios fixed, absolute sizes scaled).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple

from repro.configs.base import SimConfig


DIES_PER_CHANNEL = 64  # Table II: 8 chips/channel x 8 dies/chip
TRANSFER_NS = 800.0  # 4KB page over the channel bus (~5 GB/s ONFI bus)


class Channels:
    """Flash timing model: per-channel bus + per-die busy timelines.

    Table II's geometry (16 channels x 8 chips x 8 dies = 1024 dies) means
    tProg/tR occupy a *die* while the channel bus is only held for the 4KB
    transfer — programs overlap massively across dies (this is what makes
    write-back SSDs viable at all). Algorithm 1's estimator reads this
    queue state exactly as the paper's FTL does.
    """

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.bus = [0.0] * cfg.n_channels
        self.die = [[0.0] * DIES_PER_CHANNEL for _ in range(cfg.n_channels)]
        self.busy_ns = 0.0  # total bus-occupied ns (bandwidth accounting)
        self.reads = 0
        self.writes = 0
        self.gc_events = 0

    def channel_of(self, page: int) -> int:
        return (page * 1103515245 + 12345) % self.cfg.n_channels

    def die_of(self, page: int) -> int:
        return (page // self.cfg.n_channels) % DIES_PER_CHANNEL

    def estimate(self, page: int, now: float) -> float:
        """Algorithm 1: queued delay + read latency for this page's die/bus."""
        ch = self.channel_of(page)
        d = self.die_of(page)
        wait = max(self.die[ch][d] - now, self.bus[ch] - now, 0.0)
        return wait + self.cfg.flash.read_ns

    def read(self, page: int, now: float) -> float:
        """Issue a flash page read; returns data-available time."""
        ch = self.channel_of(page)
        d = self.die_of(page)
        start = max(now, self.die[ch][d])
        sensed = start + self.cfg.flash.read_ns
        xfer_start = max(sensed, self.bus[ch])
        done = xfer_start + TRANSFER_NS
        self.die[ch][d] = sensed
        self.bus[ch] = done
        self.busy_ns += TRANSFER_NS + self.cfg.flash.read_ns / DIES_PER_CHANNEL
        self.reads += 1
        return done

    def write(self, page: int, now: float) -> float:
        """Issue a flash program; bus for the transfer, die for tProg."""
        ch = self.channel_of(page)
        d = self.die_of(page)
        xfer_start = max(now, self.bus[ch])
        self.bus[ch] = xfer_start + TRANSFER_NS
        start = max(xfer_start + TRANSFER_NS, self.die[ch][d])
        done = start + self.cfg.flash.program_ns
        self.die[ch][d] = done
        self.busy_ns += TRANSFER_NS + self.cfg.flash.program_ns / DIES_PER_CHANNEL
        self.writes += 1
        return done

    def gc(self, now: float) -> None:
        """Occupy one die with erase + valid-page migration (plus bus time
        for the migrated pages)."""
        cfg = self.cfg
        ch = self.gc_events % cfg.n_channels
        d = self.gc_events % DIES_PER_CHANNEL
        cost = cfg.flash.erase_ns + 8 * (cfg.flash.read_ns + cfg.flash.program_ns)
        self.die[ch][d] = max(now, self.die[ch][d]) + cost
        self.bus[ch] = max(now, self.bus[ch]) + 8 * TRANSFER_NS
        self.busy_ns += cost / DIES_PER_CHANNEL
        self.gc_events += 1


class Ftl:
    """Free-page accounting driving the GC model."""

    def __init__(self, cfg: SimConfig, channels: Channels):
        self.cfg = cfg
        self.channels = channels
        self.total_pages = max(cfg.n_flash_pages, 1)
        self.used = int(self.total_pages * cfg.gc_threshold)  # preconditioned

    def on_flash_write(self, now: float) -> None:
        self.used += 1  # out-of-place update consumes a free page
        if self.used >= self.total_pages:
            self.channels.gc(now)
            self.used -= max(int(self.total_pages * (1.0 - self.cfg.gc_threshold)), 1)


class WriteLog:
    """Double-buffered cacheline write log with two-level indexing."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.cap = max(cfg.log_entries // 2, 16)  # per buffer (double-buffered)
        self.active: Dict[int, Dict[int, bool]] = {}
        self.active_n = 0
        self.old: Dict[int, Dict[int, bool]] = {}
        self.compactions = 0
        self.flushed_pages = 0
        self.flushed_lines = 0

    def lookup(self, page: int, line: int) -> bool:
        e = self.active.get(page)
        if e is not None and line in e:
            return True
        e = self.old.get(page)
        return e is not None and line in e

    def append(self, page: int, line: int) -> bool:
        """Returns True if this append filled the active log (compaction)."""
        e = self.active.get(page)
        if e is None:
            e = self.active[page] = {}
        if line not in e:
            e[line] = True
            self.active_n += 1
        return self.active_n >= self.cap

    def bulk_append_new(self, pages, lines) -> None:
        """Append a batch of (page, line) entries in order (page insertion
        order is observable at compaction time through the channel
        timeline). Entries already present are skipped exactly as append()
        would — callers may pass writes whose pair arrived since they were
        classified. Used by the batched engine; the batch is bounded so the
        log can never fill mid-batch (the engine's fill prediction counts
        candidate-new pairs, an overestimate of the true fill level)."""
        act = self.active
        n = self.active_n
        for p, l in zip(pages.tolist(), lines.tolist()):
            e = act.get(p)
            if e is None:
                act[p] = {l: True}
                n += 1
            elif l not in e:
                e[l] = True
                n += 1
        self.active_n = n

    def swap_for_compaction(self) -> Dict[int, Dict[int, bool]]:
        old = self.active
        self.old = old
        self.active = {}
        self.active_n = 0
        self.compactions += 1
        return old

    def finish_compaction(self) -> None:
        self.old = {}


class DataCache:
    """Set-associative page-granular LRU write-back cache."""

    def __init__(self, cfg: SimConfig, n_pages: Optional[int] = None):
        self.cfg = cfg
        cap = n_pages if n_pages is not None else cfg.cache_pages
        self.ways = max(cfg.cache_ways, 1)
        self.n_sets = max(cap // self.ways, 1)
        self.sets = [OrderedDict() for _ in range(self.n_sets)]
        self.hits = 0
        self.misses = 0

    def _set(self, page: int) -> OrderedDict:
        return self.sets[page % self.n_sets]

    def lookup(self, page: int, touch: bool = True) -> Optional[bool]:
        """Returns dirty-bit if present else None."""
        s = self._set(page)
        d = s.get(page)
        if d is None:
            return None
        if touch:
            s.move_to_end(page)
        return d

    def insert(self, page: int, dirty: bool) -> Optional[Tuple[int, bool]]:
        """Insert/overwrite; returns evicted (page, dirty) if any."""
        s = self._set(page)
        if page in s:
            s[page] = s[page] or dirty
            s.move_to_end(page)
            return None
        evicted = None
        if len(s) >= self.ways:
            evicted = s.popitem(last=False)
        s[page] = dirty
        return evicted

    def mark_dirty(self, page: int) -> None:
        s = self._set(page)
        if page in s:
            s[page] = True

    def touch_many(self, pages) -> None:
        """Refresh LRU recency for a batch of resident pages, in order."""
        sets = self.sets
        n_sets = self.n_sets
        for p in pages:
            s = sets[p % n_sets]
            s.move_to_end(p)

    def remove(self, page: int) -> None:
        self._set(page).pop(page, None)
