"""Deterministic device fault model + crash-consistent recovery.

Every fault decision is a pure function of ``FaultConfig`` and the
device's flash-read ordinal: per-read draws come from a splitmix64-style
counter hash over ``(fault_seed, read ordinal, salt)``, and the scheduled
events (power loss, die failure) trigger when the ordinal hits a value in
their config tuple. Both replay engines issue flash reads in the identical
order (that ordering is what the parity suites already pin down), so they
consume the identical fault stream and stay bit-exact — there is no
wall-clock, no global RNG, and no per-engine state.

Wiring: ``Machine.__init__`` attaches a ``FaultModel`` to
``Channels.fault`` when any knob is nonzero. ``Channels.read`` dispatches
to :meth:`FaultModel.read`, which mirrors its timing verbatim and layers
the fault machinery on top. The batched engine treats fault-affected
cells as a conflict class: ``run_fused`` refuses to run with a fault
model attached (falling back to the scheduler + ``batched_quantum``,
whose boundary transcription already routes every flash read through
``Channels.read``), and the scalar ``_inline_span`` calls the bound
``Channels.read`` instead of its inlined timing mirror at its three
miss sites. Zero-fault configs construct no FaultModel at all — the hot
paths pay a single ``is not None`` test.

Fault classes (see FaultConfig in configs/base.py for knob rationale):

  * **ECC read-retry ladder** — with probability ``read_error_rate`` the
    first sense fails; retry step ``k`` is still failing while
    ``u < read_error_rate * retry_fail_ratio**k``. Each step adds
    ``retry_step_ns`` (default: one full re-sense) to the die's busy
    time. A read that walks off the ladder is **uncorrectable**: it
    completes at max-ladder latency and is counted toward UBER — the
    device returns poison, it does not hang.
  * **Transient outages** — with probability ``outage_rate`` the target
    die is unavailable for ``outage_ns`` before service starts.
  * **Whole-die hard failure** — at a scheduled read ordinal the die that
    read targeted fails permanently: ``BlockFtl.fail_die`` marks its
    blocks bad, prunes the free pool, reopens any frontier that lived
    there and migrates the valid pages out through the normal program
    path. Requires the block FTL backend.
  * **Power loss** — at a scheduled read ordinal the device restarts.
    Volatile state dies: in-flight die operations are cut, the SSD-DRAM
    page cache is dropped (dirty pages counted as lost). The cacheline
    write log is DURABLE (the paper's §III-B persistence claim): every
    logged page is replayed against the FTL as an ordinary out-of-place
    program, which is idempotent — replaying twice only burns spare
    space, the l2p stays consistent. The device serves again only after
    the replay programs plus ``recovery_scan_ns`` complete; the
    triggering read's latency IS the host-visible recovery tail.

Degradation: spare-pool exhaustion (``BlockFtl._pop_free`` on an empty
pool, e.g. after die failures ate the over-provisioning) no longer raises
— the device enters a read-only degraded mode (``DeviceState.ft_degraded``)
and every subsequent program is counted as a host-visible write error.
"""
from __future__ import annotations

from repro.core.device_state import DIES_PER_CHANNEL, DeviceState
from repro.core.ssd import TRANSFER_NS

_MASK = (1 << 64) - 1
_SALT_RETRY = 0x243F6A8885A308D3   # pi digits; any fixed odd constants do
_SALT_OUTAGE = 0x13198A2E03707344


def _u01(seed: int, idx: int, salt: int) -> float:
    """Counter-based uniform draw in [0, 1): splitmix64 finalizer over a
    linear combination of (seed, ordinal, salt). Pure int math — identical
    on every platform and trivially identical across both engines."""
    z = (seed * 0x9E3779B97F4A7C15 + idx * 0xBF58476D1CE4E5B9
         + salt) & _MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    z ^= z >> 31
    return (z >> 11) * (1.0 / (1 << 53))


class FaultModel:
    """Per-device fault injector bound to ``Channels.fault``.

    Holds only config-derived scalars and the two scheduled-event sets;
    all mutable accounting lives on DeviceState (``ft_*``) so Stats folds
    it like everything else and parity compares it like everything else.
    """

    __slots__ = ("cfg", "s", "channels", "ftl", "seed", "err_rate",
                 "fail_ratio", "steps", "step_ns", "outage_rate",
                 "outage_ns", "_pl_sched", "_df_sched")

    def __init__(self, cfg, state: DeviceState, channels, ftl):
        fc = cfg.fault
        self.cfg = cfg
        self.s = state
        self.channels = channels
        self.ftl = ftl
        self.seed = int(fc.fault_seed)
        self.err_rate = float(fc.read_error_rate)
        self.fail_ratio = float(fc.retry_fail_ratio)
        self.steps = max(int(fc.retry_steps), 1)
        self.step_ns = float(fc.retry_step_ns) or float(cfg.flash.read_ns)
        self.outage_rate = float(fc.outage_rate)
        self.outage_ns = float(fc.outage_ns)
        self._pl_sched = set(int(i) for i in fc.power_loss_at)
        self._df_sched = set(int(i) for i in fc.die_fail_at)
        if self._df_sched and not hasattr(ftl, "fail_die"):
            raise ValueError(
                "FaultConfig.die_fail_at requires the block FTL backend "
                "(hard failures remap through the free pool; the legacy "
                "counter has no notion of physical blocks)")

    # ---- the Channels.read service path under faults ----

    def read(self, ch: int, d: int, now: float, gc_attr: bool = True) -> float:
        """Mirror of ``Channels.read`` (KEEP IN SYNC with ssd.py) with the
        fault machinery layered in. Scheduled power loss fires BEFORE the
        read's timing (the read then waits out the whole recovery); a
        scheduled die failure fires AFTER it (the read that "detected" the
        failure still returns its data)."""
        s = self.s
        idx = s.flash_reads  # ordinal of THIS read, pre-increment
        if self._pl_sched and idx in self._pl_sched:
            self._pl_sched.discard(idx)
            self._power_loss(now)
        chn = self.channels
        die = s.chan_die[ch]
        dv = die[d]
        o = s.obs
        booked = 0.0  # GC pause booked on this read (obs chain slot)
        if gc_attr and dv > now:
            gu = s.gc_die_until[ch][d]
            if gu > now:
                gf = s.gc_die_from[ch][d]
                lo = now if now > gf else gf
                hi = dv if dv < gu else gu
                pause = hi - lo
                if pause > 0.0:
                    s.gc_stall_events += 1
                    s.gc_pause_ns_total += pause
                    if o is not None:
                        o.gc_pause_site += pause  # bit-exact mirror
                    if pause > s.gc_pause_max_ns:
                        s.gc_pause_max_ns = pause
                    booked = pause
        start = now if now > dv else dv
        out_ns = 0.0
        if self.outage_rate > 0.0 and \
                _u01(self.seed, idx, _SALT_OUTAGE) < self.outage_rate:
            if o is not None:
                o.on_outage(ch, d, start, start + self.outage_ns)
            start += self.outage_ns
            out_ns = self.outage_ns
            s.ft_outage_events += 1
            s.ft_outage_ns += self.outage_ns
        sense = chn.read_ns
        retry_ns = 0.0
        if self.err_rate > 0.0:
            u = _u01(self.seed, idx, _SALT_RETRY)
            if u < self.err_rate:
                retries = 1
                thr = self.err_rate * self.fail_ratio
                while retries < self.steps and u < thr:
                    retries += 1
                    thr *= self.fail_ratio
                s.ft_retry_reads += 1
                s.ft_retry_steps += retries
                if u < thr:  # the whole ladder failed: ECC poison
                    s.ft_uncorrectable += 1
                retry_ns = retries * self.step_ns
                sense += retry_ns
                if o is not None:
                    o.on_retry(ch, d, now, retries)
        sensed = start + sense
        bus = s.chan_bus[ch]
        xfer_start = sensed if sensed > bus else bus
        done = xfer_start + TRANSFER_NS
        die[d] = sensed
        s.chan_bus[ch] = done
        s.chan_busy_ns += TRANSFER_NS + chn.read_ns / DIES_PER_CHANNEL
        s.flash_reads += 1
        if self._df_sched and idx in self._df_sched:
            self._df_sched.discard(idx)
            self.ftl.fail_die(now, ch, d)
            if o is not None:
                o.on_die_fail(ch, d, now)
        if o is not None and gc_attr:
            dw = dv - now  # die backlog at issue (pre-outage wait)
            if dw < 0.0:
                dw = 0.0
            rec = 0.0  # part of the wait behind a power-loss barrier
            ru = o.rec_until
            if ru > now:
                hi = dv if dv < ru else ru
                rec = hi - now
                if rec < 0.0:
                    rec = 0.0
            queue = dw - booked - rec
            if queue < 0.0:
                queue = 0.0
            o.stage_read(ch, d, now, dw, queue, booked, 0.0, rec,
                         out_ns, chn.read_ns, retry_ns,
                         xfer_start - sensed, TRANSFER_NS, done)
        return done

    # ---- power loss + crash-consistent restart ----

    def _power_loss(self, now: float) -> None:
        """Cut volatile state, replay the durable write log, and hold the
        device offline until recovery completes.

        Every timeline/array mutation here is IN PLACE: the batched
        engine's spans hold direct references to the chan_bus/chan_die/
        gc window lists and the cache arrays — rebinding any of them
        would silently fork the state the other engine sees."""
        s = self.s
        cfg = self.cfg
        n_ch = cfg.n_channels
        s.ft_power_losses += 1
        # 1) in-flight die operations (programs, reads mid-sense) are cut
        lost = 0
        for c in range(n_ch):
            die = s.chan_die[c]
            for d in range(DIES_PER_CHANNEL):
                if die[d] > now:
                    lost += 1
                    die[d] = now
            if s.chan_bus[c] > now:
                s.chan_bus[c] = now
            s.gc_die_from[c][:] = [0.0] * DIES_PER_CHANNEL
            s.gc_die_until[c][:] = [0.0] * DIES_PER_CHANNEL
        s.ft_lost_inflight += lost
        # 2) the SSD-DRAM page cache is volatile: drop everything. Dirty
        # pages whose lines were never logged are data loss (counted);
        # with the write log on, dirtiness lives in the log and survives.
        res = s.cache_res.nonzero()[0]
        if res.size:
            pages = res.tolist()
            s.ft_lost_dirty_pages += int(s.cache_dirty[res].sum())
            s.cache_res[res] = False
            s.cache_dirty[res] = False
            sets, way, n_sets = s.cache_sets, s.cache_way, s.cache_n_sets
            for p in pages:
                w = way[p]
                if w >= 0:
                    sets[p % n_sets][w] = -1
                    way[p] = -1
            s.bump_list(pages)
        # 3) replay the DURABLE cacheline log (both buffers, insertion
        # order, deduped): each page becomes one ordinary out-of-place
        # program. Idempotent by construction — on_flash_write only
        # remaps; the log dicts themselves are NOT cleared (the log is
        # persistent media and compaction owns its lifecycle — this also
        # keeps the engines' hoisted log references valid).
        replayed = 0
        if s.log_old or s.log_active:
            seen = {}
            if s.log_old:
                for p in s.log_old:
                    seen[p] = True
            if s.log_active:
                for p in s.log_active:
                    seen[p] = True
            wr = self.ftl.on_flash_write
            for p in seen:
                wr(now, p)
                replayed += 1
        s.ft_replayed_pages += replayed
        # 4) recovery barrier: the device answers nothing until the replay
        # programs drain plus the firmware restart scan. Every timeline is
        # pushed to the barrier so the next read on ANY die pays the tail.
        end = now
        for c in range(n_ch):
            if s.chan_bus[c] > end:
                end = s.chan_bus[c]
            for t in s.chan_die[c]:
                if t > end:
                    end = t
        end += cfg.fault.recovery_scan_ns
        for c in range(n_ch):
            s.chan_bus[c] = end
            s.chan_die[c][:] = [end] * DIES_PER_CHANNEL
            # replay-driven GC carved windows inside the outage; the host
            # never saw them — recovery time must not book as GC pause
            s.gc_die_from[c][:] = [0.0] * DIES_PER_CHANNEL
            s.gc_die_until[c][:] = [0.0] * DIES_PER_CHANNEL
        dt = end - now
        s.ft_recovery_ns_total += dt
        if dt > s.ft_recovery_ns_max:
            s.ft_recovery_ns_max = dt
        o = s.obs
        if o is not None:  # barrier event + recovery attribution horizon
            o.on_recovery(now, end)
