"""SkyByte tiering runtime for TPU serving (DESIGN.md §2 Layer B).

The paper's memory system, re-expressed for an LLM serving engine:

  flash chips            -> host-tier page pool (big, slow to reach)
  SSD DRAM data cache    -> HBM page pool (fast, small)
  cacheline write log    -> token-granular KV write-log ring in HBM
  log compaction         -> kernels/log_compact: newest-wins coalescing of
                            log tokens into page-granular pool writes
  page-granular flash IO -> page-granular host<->HBM copies
  adaptive migration     -> hot-page promotion into the HBM pool (engine
                            policy; LRU eviction under pressure)
  coordinated ctx switch -> the serving scheduler parks requests whose
                            pages are not HBM-resident (predicted-slow,
                            Algorithm-1-style estimate) and runs others

All device state is a flat dict of fixed-shape arrays (jit/pjit friendly);
policy (promotion targets, flush targets, scheduling) is host-side, exactly
as the paper splits FTL policy (firmware) from the data path (hardware).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.kv_log_append.ref import kv_log_append_ref
from repro.kernels.log_compact.ops import log_compact
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.models.api import ModelSpec
from repro.models.dense import _attn_params, _ffn, unembed
from repro.models.layers import project_qkv, rmsnorm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TieredKVConfig:
    page_size: int = 16
    n_hbm_pages: int = 32  # HBM pool slots (the "SSD DRAM cache")
    max_requests: int = 8
    max_pages_per_req: int = 8
    log_slots: int = 64
    batch: int = 4  # decode batch width (scheduled requests per step)
    promote_pages_per_step: int = 4  # host->HBM copy budget per step
    fetch_page_us: float = 50.0  # per-page host->HBM latency estimate
    park_threshold_us: float = 50.0  # Algorithm-1-style switch threshold

    @property
    def n_host_pages(self) -> int:
        return self.max_requests * self.max_pages_per_req


def init_state(
    kv_cfg: TieredKVConfig, cfg: ModelConfig, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    c = kv_cfg
    shape_pool = (L, c.n_hbm_pages, c.page_size, KV, hd)
    shape_host = (L, c.n_host_pages, c.page_size, KV, hd)
    return {
        "hbm_k": jnp.zeros(shape_pool, dtype),
        "hbm_v": jnp.zeros(shape_pool, dtype),
        "host_k": jnp.zeros(shape_host, dtype),
        "host_v": jnp.zeros(shape_host, dtype),
        "page_table": -jnp.ones((c.max_requests, c.max_pages_per_req), jnp.int32),
        "log_k": jnp.zeros((L, c.log_slots, KV, hd), dtype),
        "log_v": jnp.zeros((L, c.log_slots, KV, hd), dtype),
        "log_meta": -jnp.ones((c.log_slots, 2), jnp.int32),
        "log_tail": jnp.zeros((), jnp.int32),
        "lengths": jnp.zeros((c.max_requests,), jnp.int32),
        # compaction watermark: positions < compacted live in pages;
        # positions >= compacted live in the write log (disjointness)
        "compacted": jnp.zeros((c.max_requests,), jnp.int32),
    }


def host_slot(kv_cfg: TieredKVConfig, req: int, logical: int) -> int:
    """Backing-store slot for a request's logical page (direct-mapped)."""
    return req * kv_cfg.max_pages_per_req + logical


# ---------------------------------------------------------------------------
# device ops
# ---------------------------------------------------------------------------


def copy_pages(dst_k, dst_v, src_k, src_v, pairs: jax.Array):
    """Copy pages src->dst pool. pairs: (F, 2) int32 (src_slot, dst_slot),
    -1 rows ignored. Models the page-granular host<->HBM DMA."""
    src, dst = pairs[:, 0], pairs[:, 1]
    valid = (src >= 0) & (dst >= 0)
    ssafe = jnp.maximum(src, 0)
    dsafe = jnp.maximum(dst, 0)
    cur_k = dst_k[:, dsafe]
    cur_v = dst_v[:, dsafe]
    new_k = jnp.where(valid[None, :, None, None, None], src_k[:, ssafe], cur_k)
    new_v = jnp.where(valid[None, :, None, None, None], src_v[:, ssafe], cur_v)
    return dst_k.at[:, dsafe].set(new_k), dst_v.at[:, dsafe].set(new_v)


def write_prefill_pages(kv_cfg: TieredKVConfig, state, req: int, k, v):
    """Scatter a dense prefill cache (L, S, KV, hd) into the request's
    host-tier pages (the paper's initial placement: data starts in the
    slow tier)."""
    L, S, KV, hd = k.shape
    p = kv_cfg.page_size
    n = (S + p - 1) // p
    pad = n * p - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pages_k = k.reshape(L, n, p, KV, hd)
    pages_v = v.reshape(L, n, p, KV, hd)
    base = host_slot(kv_cfg, req, 0)
    state = dict(state)
    state["host_k"] = jax.lax.dynamic_update_slice_in_dim(
        state["host_k"], pages_k.astype(state["host_k"].dtype), base, axis=1
    )
    state["host_v"] = jax.lax.dynamic_update_slice_in_dim(
        state["host_v"], pages_v.astype(state["host_v"].dtype), base, axis=1
    )
    state["lengths"] = state["lengths"].at[req].set(S)
    state["compacted"] = state["compacted"].at[req].set(S)
    return state


def build_paged_decode_step(
    spec: ModelSpec, kv_cfg: TieredKVConfig, *, use_pallas: bool = False
):
    """Decode step over the tiered KV state for GQA decoder families
    (dense/moe/vlm). Returns step(params, state, tokens, req_ids) ->
    (next_tokens, new_state).

    The current token's K/V is appended to the write log (token-granular,
    no page read-modify-write — the paper's write path) and the attention
    reads pages + log in parallel (the paper's read path).
    """
    cfg = spec.cfg

    def step(params, state, tokens, req_ids):
        B = tokens.shape[0]
        safe_req = jnp.maximum(req_ids, 0)
        lengths = jnp.where(req_ids >= 0, state["lengths"][safe_req], 0)  # (B,)
        compacted = jnp.where(req_ids >= 0, state["compacted"][safe_req], 0)
        page_table = state["page_table"][safe_req]  # (B, N)

        x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, d)
        positions = lengths[:, None]

        tail = state["log_tail"]
        meta_rows = jnp.stack(
            [req_ids, jnp.where(req_ids >= 0, lengths, -1)], axis=-1
        )
        log_meta = jax.lax.dynamic_update_slice_in_dim(
            state["log_meta"], meta_rows, tail, axis=0
        )

        def body(x, xs):
            p_l, hbm_k_l, hbm_v_l, log_k_l, log_v_l = xs
            h = rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
            q, k, v = project_qkv(cfg, _attn_params(cfg, p_l), h, positions)
            # write path: append this token's KV to the log (per layer)
            log_k_l = jax.lax.dynamic_update_slice_in_dim(
                log_k_l, k[:, 0].astype(log_k_l.dtype), tail, axis=0
            )
            log_v_l = jax.lax.dynamic_update_slice_in_dim(
                log_v_l, v[:, 0].astype(log_v_l.dtype), tail, axis=0
            )
            # read path: pages + log in parallel (lengths+1 covers the
            # just-appended token)
            o = paged_decode_attention(
                q[:, 0], hbm_k_l, hbm_v_l, page_table, lengths + 1,
                log_k_l, log_v_l, log_meta,
                page_lengths=compacted, req_ids=req_ids,
                use_pallas=use_pallas,
            )
            x2 = x + jnp.einsum("bh,hd->bd", o.reshape(B, -1), p_l["wo"])[:, None]
            h2 = rmsnorm(x2, p_l["mlp_norm"], cfg.norm_eps)
            f, _ = _ffn(cfg, p_l, h2)
            return x2 + f, (log_k_l, log_v_l)

        x, (log_k, log_v) = jax.lax.scan(
            body, x,
            (params["blocks"], state["hbm_k"], state["hbm_v"],
             state["log_k"], state["log_v"]),
        )
        logits = unembed(cfg, params, x)[:, 0]
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

        new_state = dict(state)
        new_state["log_k"] = log_k
        new_state["log_v"] = log_v
        new_state["log_meta"] = log_meta
        new_state["log_tail"] = tail + B
        new_state["lengths"] = state["lengths"].at[safe_req].add(
            (req_ids >= 0).astype(jnp.int32)
        )
        return next_tok, new_state

    return step


def compact_log(
    kv_cfg: TieredKVConfig, state, flush_hbm: jax.Array, flush_host: jax.Array
):
    """Run log compaction into both pools and clear the log.

    flush_hbm / flush_host: (F, 3) int32 (request, logical_page, pool_slot)
    built by the engine from log_meta (unique dirty pages — the paper's
    first-level hash-table scan)."""
    state = dict(state)
    state["hbm_k"], state["hbm_v"] = log_compact(
        state["hbm_k"], state["hbm_v"], state["log_k"], state["log_v"],
        state["log_meta"], flush_hbm, use_pallas=False,
    )
    state["host_k"], state["host_v"] = log_compact(
        state["host_k"], state["host_v"], state["log_k"], state["log_v"],
        state["log_meta"], flush_host, use_pallas=False,
    )
    state["log_meta"] = -jnp.ones_like(state["log_meta"])
    state["log_tail"] = jnp.zeros((), jnp.int32)
    # everything logged so far is now in pages
    state["compacted"] = state["lengths"]
    return state
