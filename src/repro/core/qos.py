"""Die-level QoS arbitration: GC suspend/resume + read-priority scheduling.

The device carves per-die GC windows ([gc_die_from, gc_die_until], see
flash._gc_once) and, without QoS, any host read targeting that die simply
waits the window out — PR 5 made the pause visible (gc_pause_ns_total),
this module shrinks it. Two mechanisms, both per-die, both applied at the
single read-arbitration point:

GC suspend/resume (``cfg.gc_suspend``)
    A host read arriving inside a carved window preempts the in-flight
    GC chain: the read waits only ``gc_suspend_ns`` (the time for the
    erase/program slice to reach a suspendable point), senses, and the
    suspended GC work resumes BEHIND it with a fixed ``gc_resume_ns``
    re-setup penalty. The die's backlog and the window are both pushed
    back by exactly ``read_ns + gc_resume_ns``. Suspends are bounded per
    window (``gc_suspend_max``, refilled at each new carve) so a read
    storm cannot starve cleaning.

Read-priority arbitration (``cfg.read_priority``)
    Two queue-jump points, one per contended resource. DIE: outside GC
    windows, a read that would queue behind more than
    ``read_priority_wait_ns`` of die backlog (host + GC programs) is
    scheduled ahead of the queued work — it waits only the cap (the
    in-flight op cannot be preempted), and the displaced programs are
    pushed back by the read's die occupancy (``read_ns``). CHANNEL BUS:
    a read whose sensed data would queue behind more than one 800ns
    transfer jumps the bus queue (write bursts convoy transfers behind
    the frontier's channel — frequently the dominant read wait, since
    programs overlap across dies but transfers serialize per channel),
    waiting at most the one in-flight transfer.

Like the fault model (core/faults.py), QoS-active reads are a CONFLICT
CLASS: ``Machine.__init__`` attaches one QosModel to ``Channels.qos``,
``Channels.read`` and the inline span's ``f_read`` sites both dispatch to
``QosModel.read``, and ``run_fused`` refuses QoS-active configs — both
engines therefore execute the identical arbitration code and stay
bit-exact by construction. Zero-QoS configs attach nothing and pay one
``is not None`` test per flash read.

All mutable accounting lives on DeviceState; this class is pure policy +
cached config scalars.
"""
from __future__ import annotations

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL, DeviceState
from repro.core.ssd import TRANSFER_NS, Channels


class QosModel:
    """Single shared read-arbitration function for both replay engines."""

    __slots__ = ("cfg", "s", "read_ns", "rd_busy",
                 "suspend", "suspend_ns", "resume_ns",
                 "rp", "rp_cap")

    def __init__(self, cfg: SimConfig, state: DeviceState,
                 channels: Channels):
        self.cfg = cfg
        self.s = state
        self.read_ns = channels.read_ns
        self.rd_busy = TRANSFER_NS + channels.read_ns / DIES_PER_CHANNEL
        self.suspend = cfg.gc_suspend
        self.suspend_ns = cfg.gc_suspend_ns
        self.resume_ns = cfg.gc_resume_ns
        self.rp = cfg.read_priority
        self.rp_cap = cfg.read_priority_wait_ns

    def read(self, ch: int, d: int, now: float,
             gc_attr: bool = True) -> float:
        """KEEP IN SYNC with ssd.Channels.read — the default (no
        mechanism engaging) path below must replay its timing recipe and
        GC-pause attribution bit-for-bit; QoS only ever REPLACES the
        blocked branches. ``gc_attr=False`` device-internal reads take the
        plain path unconditionally: no thread blocks on them, so there is
        nothing to prioritize and preempting GC for them would burn the
        bounded suspend budget on invisible latency."""
        s = self.s
        read_ns = self.read_ns
        die = s.chan_die[ch]
        dv = die[d]
        rp = self.rp and gc_attr
        booked = 0.0  # GC pause booked on this read (obs chain slot)
        if gc_attr and dv > now:
            wait = dv - now
            # per-die queue-occupancy telemetry: max backlog a host read
            # observed at issue (fig_gc_tail's occupancy column)
            if wait > s.qos_die_wait_max_ns:
                s.qos_die_wait_max_ns = wait
            gu = s.gc_die_until[ch][d]
            if gu > now:
                gf = s.gc_die_from[ch][d]
                lo = now if now > gf else gf
                hi = dv if dv < gu else gu
                pause = hi - lo
                if pause > 0.0:
                    if (self.suspend and s.gc_susp_left[ch][d] > 0
                            and pause > self.suspend_ns):
                        return self._suspend_read(ch, d, now, dv, gu, pause)
                    # budget exhausted / pause already short: standard
                    # attribution, wait the window out (Channels.read)
                    s.gc_stall_events += 1
                    s.gc_pause_ns_total += pause
                    if pause > s.gc_pause_max_ns:
                        s.gc_pause_max_ns = pause
                    o = s.obs
                    if o is not None:
                        o.gc_pause_site += pause  # bit-exact mirror
                    booked = pause
            elif rp and wait > self.rp_cap:
                # --- read-priority DIE bypass (no GC window on this die:
                # windows belong to the suspend mechanism). The read is
                # scheduled ahead of the QUEUED programs: it waits only
                # the cap (the in-flight op cannot be preempted), and the
                # displaced backlog finishes late by the read's die
                # occupancy. ---
                start = now + self.rp_cap
                sensed = start + read_ns
                nd = dv + read_ns
                die[d] = nd if nd > sensed else sensed
                s.rp_bypasses += 1
                s.rp_wait_saved_ns += wait - self.rp_cap
                done = self._xfer(ch, sensed, rp)
                o = s.obs
                if o is not None:
                    bw = (done - sensed) - TRANSFER_NS
                    o.stage_read(ch, d, now, wait, self.rp_cap, 0.0,
                                 0.0, 0.0, 0.0, read_ns, 0.0,
                                 bw if bw > 0.0 else 0.0,
                                 TRANSFER_NS, done)
                return done
        start = now if now > dv else dv
        sensed = start + read_ns
        die[d] = sensed
        done = self._xfer(ch, sensed, rp)
        if gc_attr:
            o = s.obs
            if o is not None:
                die_wait = start - now
                queue = die_wait - booked
                bw = (done - sensed) - TRANSFER_NS
                o.stage_read(ch, d, now, die_wait,
                             queue if queue > 0.0 else 0.0, booked, 0.0,
                             0.0, 0.0, read_ns, 0.0,
                             bw if bw > 0.0 else 0.0, TRANSFER_NS, done)
        return done

    def _xfer(self, ch: int, sensed: float, rp: bool) -> float:
        """Channel-bus stage of a read. Without read priority this IS
        Channels.read's tail (done = max(sensed, bus) + TRANSFER_NS, read
        queued at the bus tail). With it, a read whose data would queue
        behind more than one transfer jumps the bus queue: write bursts
        convoy 800ns transfers behind the frontier's channel (often the
        dominant read wait — programs overlap across dies but every
        transfer serializes on the channel), and an arbiter can reorder
        queued transfers even though it cannot preempt the in-flight one.
        The read therefore waits at most ONE residual transfer after its
        data is sensed, and the displaced queue finishes one transfer
        late."""
        s = self.s
        bus = s.chan_bus[ch]
        if rp and bus - sensed > TRANSFER_NS:
            done = sensed + TRANSFER_NS + TRANSFER_NS
            s.chan_bus[ch] = bus + TRANSFER_NS
            s.rp_bypasses += 1
            s.rp_wait_saved_ns += (bus - sensed) - TRANSFER_NS
        else:
            done = (sensed if sensed > bus else bus) + TRANSFER_NS
            s.chan_bus[ch] = done
        s.chan_busy_ns += self.rd_busy
        s.flash_reads += 1
        return done

    def _suspend_read(self, ch: int, d: int, now: float, dv: float,
                      gu: float, pause: float) -> float:
        """Preempt the die's GC chain for one host read.

        Timing contract (DESIGN.md "Die-level QoS"): the read senses at
        ``now + suspend_ns``; every piece of work that was scheduled
        after that instant — the suspended GC remainder (``rem``) and the
        window end — shifts back by exactly ``read_ns + resume_ns``. The
        residual ``suspend_ns`` the read still waited is booked through
        the standard gc_pause counters (it IS GC-induced), and the pause
        it dodged lands in gc_pause_avoided_ns, so
        pause_without_qos == pause_ns_total + pause_avoided_ns holds per
        suspension."""
        s = self.s
        read_ns = self.read_ns
        resume_ns = self.resume_ns
        suspend_ns = self.suspend_ns
        s.gc_susp_left[ch][d] -= 1
        start = now + suspend_ns
        rem = dv - start  # GC work displaced behind the read (> 0: the
        #                   guard requires pause > suspend_ns)
        sensed = start + read_ns
        s.chan_die[ch][d] = sensed + resume_ns + rem
        s.gc_die_until[ch][d] = gu + (read_ns + resume_ns)
        s.gc_suspends += 1
        s.gc_resumes += 1
        s.gc_resume_ns_total += resume_ns
        s.gc_pause_avoided_ns += pause - suspend_ns
        s.gc_stall_events += 1
        s.gc_pause_ns_total += suspend_ns
        if suspend_ns > s.gc_pause_max_ns:
            s.gc_pause_max_ns = suspend_ns
        done = self._xfer(ch, sensed, self.rp)
        o = s.obs
        if o is not None:
            o.gc_pause_site += suspend_ns  # bit-exact mirror (booked above)
            o.on_suspend(ch, d, now, start)
            bw = (done - sensed) - TRANSFER_NS
            # the residual suspend_ns the read waited is GC-induced: it
            # goes to the gc_suspend chain slot, not the queue slot
            o.stage_read(ch, d, now, dv - now, 0.0, 0.0, suspend_ns,
                         0.0, 0.0, read_ns, 0.0,
                         bw if bw > 0.0 else 0.0, TRANSFER_NS, done)
        return done
