"""Vectorized batched replay engine — the simulator's fast path.

The reference engine in simulator.py retires one request per Python
iteration (~100-250k req/s). This engine processes each scheduling quantum
in structure-of-arrays batches instead, and — new in this revision — keeps
the expensive part of that work (per-event *classification* against the
device state) in a **cross-quantum cache** so it is paid once per thread,
not once per quantum.

Why: SkyByte's coordinated context switches cap quanta at ~1/miss-rate
events (~50-80 on ULL flash), far below the break-even of a per-quantum
NumPy classification pass. Re-deriving the same per-page state for the
same thread every time it is rescheduled made the ctx-switch-bound cells
(SkyByte-C/Full) the slowest in the grid. The cache removes exactly that
recomputation:

  * **Classification cache** — each thread carries a classified *range*
    of its upcoming trace (``SimConfig.cls_cache_window`` events at most),
    produced by one vectorized pass into extended class codes (table
    below). A scheduling quantum then only has to find the next boundary
    (one argmax over the cached codes) and bulk-retire the prefix; the
    range survives across quanta and is re-classified only when the epoch
    check proves it stale or the thread consumes past its end.
  * **Epoch-based page-version repair** — every membership mutation bumps
    a per-page epoch counter on the machine (``BatchedMachine.page_epoch``):
    cache inserts/evictions, host promotions and demotions, and log
    compactions (which invalidate every logged line of the drained buffer
    at once). On quantum re-entry the engine takes the max epoch of the
    remaining range's pages (one gather) and compares it against the
    range's stamp — clean means the codes are provably current for the
    whole quantum (quanta are serial: no other thread can run mid-quantum)
    and the stamp advances; dirty means the range is re-classified from
    the current position in one vector pass. Mid-quantum, the only
    mutators are this thread's own boundary events; the pages they bump
    are recorded in a tiny journal and folded back in place (re-classify
    just their range positions), after which the stamp advances again.
    Log *appends* deliberately do not
    bump epochs (warm write pages are appended to constantly by every
    thread and would keep every cache dirty); line presence only grows
    between compactions, so the prefix about to be bulk-applied is instead
    brought current by a tiny targeted overlay (see _log_overlay).
  * **Fused exact accumulators** — the four sequential float chains the
    reference maintains (core time, lat_sum, lat_host, lat_hit) are
    replayed with ONE cumsum over a 4-row buffer whose unused slots are
    zero: IEEE addition of +0.0 is exact, so each row reproduces the
    reference's left-to-right addition order bit-for-bit.
  * **Inline spans** — when observed fast-run lengths drop below the cache
    break-even (``SimConfig.cls_cache_min_run``; boundary-dense phases
    such as Base-CSSD write storms), the engine switches to the tuned
    per-event loop: serve()'s state-stable cases inlined with *identical*
    operation order, full serve() only at state-changing events.

Extended class codes (int8; one per trace position):

  0 host-DRAM read hit      4 logged write, NEW (page,line) pair
  1 host-DRAM write hit     5 logged write, already-present pair
  2 write-log read hit      6 Base-CSSD cache write hit
  3 data-cache read hit     7 boundary (miss / fill / slow path)

Codes 0-6 are *state-stable*: their device-state effects are closed-form
under a snapshot. Code 7 events run the exact per-event path
(Machine.serve). Write-log fills and page promotions are *predicted*
boundaries found from the cached codes (cumulative new-pair counts vs the
log headroom; per-page running access counts vs the promotion threshold).
Store-to-load forwarding is encoded at classification time: a read of a
(page, line) pair whose first in-window write precedes it is classified a
log hit, which stays correct across quanta because any other writer of
that page bumps its epoch.

Exactness contract (enforced by tests/test_engine.py and
tests/test_engine_cache.py): for the same seed the batched engine — with
the cache on or off, under any churn — produces *identical* results to the
reference engine; integer counters bit-equal, float accumulators bit-equal
as well because bulk accumulation replays the reference's sequential
addition order.

Stochastic promotion policies ("tpp" consumes RNG per access,
"astriflash" promotes on every cache-resident touch) leave no usable
state-stable fast path; they are pinned to the inline span, whose
per-event order keeps even the RNG stream exact.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.configs.base import SimConfig
from repro.core.simulator import Machine, Thread, _record, _replay_prologue
from repro.core.ssd import DataCache, WriteLog

# Vectorization break-even WITHOUT the classification cache: below this
# expected fast-run length the inline per-event span loop beats per-chunk
# NumPy classify + dispatch overhead. (With the cache the break-even is
# SimConfig.cls_cache_min_run, far lower: classification is pre-paid.)
_VEC_MIN = 192
_CHUNK_MAX = 8192
_CHUNK_FLOOR = 64
# Events to replay inline before re-probing vectorization.
_SPAN = 1024

# Cross-quantum classification-cache observability (per process; reset by
# simulate() at the start of every batched run). benchmarks/run.py folds
# these into BENCH_sim.json's engine calibration section.
CACHE_STATS = {
    "builds": 0,      # range classifications due to range exhaustion/first use
    "checks": 0,      # quantum re-entry epoch validations of a live range
    "clean": 0,       # validations whose range pages were all unchanged (hits)
    "repairs": 0,     # dirty validations -> range re-classified in place
    "folds": 0,       # boundary-event page sets folded back mid-quantum
    "classified": 0,  # total events classified (amortization denominator)
}


def reset_cache_stats() -> None:
    for k in CACHE_STATS:
        CACHE_STATS[k] = 0


def cache_hit_rate() -> float:
    """Fraction of re-entry validations that consumed cached codes as-is."""
    v = CACHE_STATS["checks"]
    return CACHE_STATS["clean"] / v if v else 0.0


def cache_repair_rate() -> float:
    """Fraction of re-entry validations that re-classified the range."""
    v = CACHE_STATS["checks"]
    return CACHE_STATS["repairs"] / v if v else 0.0


def supported(cfg: SimConfig) -> bool:
    """Whether the batched engine reproduces this config exactly.

    Always true today: stochastic promotion policies (tpp/astriflash) are
    handled by the inline span, which consumes the RNG in the reference's
    per-event order; only the vector path is disabled for them (see
    BatchedMachine._inline_only). Kept as an explicit hook for future
    configs that might need the reference loop.
    """
    return True


class _ArrayCounts:
    """Dense per-page promotion counters, API-compatible with the dict
    Machine.acc_count (only .get and item assignment are used)."""

    __slots__ = ("arr",)

    def __init__(self, page_space: int):
        self.arr = np.zeros(page_space, np.int64)

    def get(self, page: int, default: int = 0) -> int:
        return int(self.arr[page])

    def __setitem__(self, page: int, value: int) -> None:
        self.arr[page] = value


class _ShadowHost(OrderedDict):
    """Host-DRAM LRU with a dense membership mirror and epoch bumps on
    membership changes. Scalar mirror writes go through a memoryview
    (~4x cheaper than NumPy scalar indexing); the ndarray view is what
    the vector path fancy-indexes."""

    def __init__(self, machine: "BatchedMachine", page_space: int):
        super().__init__()
        self.arr = np.zeros(page_space, bool)
        self._mv = memoryview(self.arr)
        self._m = machine

    def __setitem__(self, page, value) -> None:
        super().__setitem__(page, value)
        self._mv[page] = True
        self._m._bump(page)

    def popitem(self, last: bool = True):
        page, value = super().popitem(last)
        self._mv[page] = False
        self._m._bump(page)
        return page, value


class _ShadowCache(DataCache):
    """DataCache with a dense membership mirror (memoryview for scalar
    writes, ndarray for the vector path's bulk reads) and epoch bumps on
    inserts/evictions/removals."""

    def __init__(self, machine: "BatchedMachine", cfg: SimConfig, page_space: int):
        super().__init__(cfg)
        self.arr = np.zeros(page_space, bool)
        self._mv = memoryview(self.arr)
        self._m = machine

    def insert(self, page, dirty):
        ev = super().insert(page, dirty)
        self._mv[page] = True
        self._m._bump(page)
        if ev is not None:
            self._mv[ev[0]] = False
            self._m._bump(ev[0])
        return ev

    def remove(self, page) -> None:
        super().remove(page)
        self._mv[page] = False
        self._m._bump(page)


class _ShadowLog(WriteLog):
    """WriteLog with a per-page 64-bit line-presence bitmask mirror of the
    active buffer (the old buffer is only non-empty inside _compact, which
    never overlaps the fast path).

    Appends do NOT bump epochs: line presence only ever *grows* between
    compactions, so cached codes are brought current by the cheap per-chunk
    log overlay in batched_quantum (reads of now-present lines -> log hits,
    new-pair writes -> duplicates) instead of by page repair — warm write
    pages are appended to constantly by every thread, and bumping them
    would keep every cache permanently dirty. A compaction breaks the
    monotonicity (lines vanish all at once), so it bumps every page the
    drained buffer held."""

    def __init__(self, machine: "BatchedMachine", cfg: SimConfig, page_space: int):
        super().__init__(cfg)
        self.bits = np.zeros(page_space, np.uint64)
        self._m = machine

    def append(self, page, line):
        self.bits[page] |= np.uint64(1 << line)
        return super().append(page, line)

    def bulk_append_new(self, pages: np.ndarray, lines: np.ndarray) -> None:
        # bitwise_or.at: pages may repeat within a batch (several new lines
        # of one page); plain fancy-index |= would drop all but one OR.
        # Setting bits for pairs the dup-tolerant base append then skips is
        # harmless — they are already present by definition.
        np.bitwise_or.at(self.bits, pages, np.uint64(1) << lines.astype(np.uint64))
        super().bulk_append_new(pages, lines)

    def swap_for_compaction(self):
        self.bits[:] = 0
        old_pages = list(self.active)
        if old_pages:
            self._m._bump_list(old_pages)
        return super().swap_for_compaction()


class _ClsCache:
    """Per-thread cross-quantum classification cache.

    ``codes[lo:hi]`` holds the extended class code of every trace position
    in the cached range, classified against the device state at epoch
    ``stamp``. A chunk whose pages' epochs are all <= stamp consumes the
    codes as-is; anything else re-classifies the range from the current
    position (one vector pass — cheaper than surgically patching pages,
    whose stale sets only grow)."""

    __slots__ = ("codes", "lo", "hi", "stamp")

    def __init__(self, n: int):
        self.codes = np.empty(n, np.int8)
        self.lo = 0
        self.hi = 0
        self.stamp = -1


class BatchedMachine(Machine):
    """Machine whose device structures carry dense NumPy mirrors plus
    per-page epoch counters, so whole chunks of the trace can be
    classified without per-event Python — and stay classified across
    scheduling quanta."""

    def __init__(self, cfg: SimConfig, seed: int, page_space: int):
        super().__init__(cfg, seed)
        self.page_space = page_space
        # --- epoch board: every membership mutation (host / cache /
        # compaction) bumps the touched page's epoch; classification
        # caches compare range page epochs against their stamp. The
        # journal names the pages bumped by the boundary event in flight
        # so they can be folded back into the live cache immediately ---
        self.page_epoch = np.zeros(page_space, np.int64)
        self._epoch_mv = memoryview(self.page_epoch)
        self.epoch_clock = 0
        self.journal: list = []
        self.cache = _ShadowCache(self, cfg, page_space)
        if cfg.enable_write_log:
            self.log = _ShadowLog(self, cfg, page_space)
        self.host = _ShadowHost(self, page_space)
        self.acc_count = _ArrayCounts(page_space)
        # stochastic promotion consumes RNG per access: only the strictly
        # per-event inline span preserves the draw order
        self._inline_only = cfg.enable_promotion and cfg.promo_policy != "skybyte"
        self._use_cache = (cfg.cls_cache and not self._inline_only
                           and not cfg.dram_only)
        self._min_run = cfg.cls_cache_min_run if self._use_cache else _VEC_MIN
        self._window = max(int(cfg.cls_cache_window), 1)
        self._caches: dict = {}  # tid -> _ClsCache
        self.chunk = 512  # adaptive: grows on clean chunks, shrinks at boundaries
        # EWMA of fast-run length (events between state-changing boundaries);
        # decides vector chunks vs the inline span loop. Start optimistic so
        # boundary-free configs (dram-only) stay vectorized from event one.
        self.runlen = float(_VEC_MIN)
        self._cols = {}  # tid -> native-list trace columns (inline span path)
        # fast-path latency constants — same expressions as Machine.serve
        base = cfg.cxl_protocol_ns
        lat_host = cfg.host_dram_ns
        lat_log = base + cfg.log_index_ns + cfg.ssd_dram_ns
        lat_cache = base + cfg.cache_index_ns + cfg.ssd_dram_ns
        # per extended class code (0-7; boundary gets 0, never used)
        self._lat_lut8 = np.array([lat_host, lat_host, lat_log, lat_cache,
                                   lat_log, lat_log, lat_cache, 0.0])
        self._lat_log = lat_log
        self._counting = cfg.enable_promotion and cfg.promo_policy == "skybyte"

    # ---- epoch bumps (called by the shadow structures) ----
    def _bump(self, page: int) -> None:
        c = self.epoch_clock + 1
        self.epoch_clock = c
        self._epoch_mv[page] = c
        self.journal.append(page)

    def _bump_list(self, pages: list) -> None:
        c = self.epoch_clock + len(pages)
        self.epoch_clock = c
        self.page_epoch[pages] = c
        self.journal.extend(pages)

    def _columns(self, th: Thread):
        cols = self._cols.get(th.tid)
        if cols is None:
            cols = (th.page.tolist(), th.line.tolist(), th.write.tolist(),
                    th.gap64.tolist())
            self._cols[th.tid] = cols
        return cols


def _last_occurrence_order(pages: np.ndarray):
    """Unique pages ordered by their LAST occurrence. Applying one
    move-to-end per page in this order reproduces the final LRU order of
    the reference's per-event touches."""
    # dict.fromkeys keeps first-seen order; feeding the reversed sequence
    # makes that last-seen order, reversed back to ascending position
    d = dict.fromkeys(reversed(pages.tolist()))
    return reversed(d)


def _classify_positions(m: BatchedMachine, cfg: SimConfig, pg, ln, wr):
    """Extended class codes for a batch of trace events against the current
    state snapshot.

    The batch may be a contiguous trace slice OR any gather of positions,
    as long as same-page events appear in ascending trace order: the
    newness / store-to-load-forwarding logic groups by (page, line) pair,
    and pairs never span pages, so per-page ascending order is the only
    ordering it observes."""
    if cfg.dram_only:
        return wr.astype(np.int8)
    k = pg.shape[0]
    hostm = m.host.arr[pg]
    cachem = m.cache.arr[pg]
    if m.log is None:
        return np.where(
            hostm, wr.astype(np.int8),
            np.where(cachem,
                     np.where(wr, np.int8(6), np.int8(3)),
                     np.int8(7)),
        ).astype(np.int8)
    linem = (m.log.bits[pg] >> ln.astype(np.uint64)) & np.uint64(1) != 0
    new = np.zeros(k, bool)
    logged = linem
    wmask = wr & ~hostm
    widx = np.flatnonzero(wmask)
    if widx.size:
        pairs = pg * 64 + ln
        wp = pairs[widx]
        order = np.argsort(wp, kind="stable")
        sw = wp[order]
        first = np.empty(sw.size, bool)
        first[0] = True
        np.not_equal(sw[1:], sw[:-1], out=first[1:])
        fidx = widx[order[first]]  # earliest in-batch write per pair
        new[fidx] = ~linem[fidx]
        # forwarding: any event on the pair AFTER its first write sees the
        # appended line (the reference's log.lookup would by then)
        upairs = sw[first]
        loc = np.searchsorted(upairs, pairs)
        loc[loc == upairs.size] = 0  # clamp; mismatch check below rejects
        logged = linem | ((upairs[loc] == pairs) & (fidx[loc] < np.arange(k)))
    wcodes = np.where(new, np.int8(4), np.int8(5))
    rcodes = np.where(logged, np.int8(2),
                      np.where(cachem, np.int8(3), np.int8(7)))
    return np.where(hostm, wr.astype(np.int8),
                    np.where(wr, wcodes, rcodes)).astype(np.int8)


def _refresh_cache(m: BatchedMachine, cfg: SimConfig, th: Thread,
                   cc: _ClsCache, i: int, want: int) -> None:
    """(Re)classify the thread's cached range starting at position i,
    covering at least ``want`` events. The range scales with the adaptive
    chunk (boundary-dense phases keep refreshes cheap, stable phases
    amortize over tens of thousands of events), capped by the
    ``SimConfig.cls_cache_window`` knob."""
    r = min(th.n, i + max(min(4 * m.chunk, m._window), want))
    cc.codes[i:r] = _classify_positions(m, cfg, th.page[i:r], th.line[i:r],
                                        th.write[i:r])
    cc.lo = i
    cc.hi = r
    cc.stamp = m.epoch_clock
    CACHE_STATS["classified"] += r - i


def _log_overlay(m: BatchedMachine, th: Thread, i: int, b: int,
                 pg, ln, codes) -> None:
    """Fold write-log lines appended since classification into the prefix
    about to be applied. Line presence only grows between compactions
    (which bump epochs and take the repair path), so the only stale code
    that could corrupt bulk application is a cache-read-hit whose line is
    now logged (3 -> 2: the reference checks the log before the cache).
    Stale NEW-pair writes are absorbed by the dup-tolerant bulk append,
    and a read-miss that became a log hit (7) stays a boundary that
    serve() resolves exactly."""
    fc = codes[:b]
    aff = np.flatnonzero(fc == 3)
    if aff.size:
        linem = (m.log.bits[pg[aff]] >> ln[aff].astype(np.uint64)) \
            & np.uint64(1) != 0
        if linem.any():
            fc[aff[linem]] = 2


def _next_boundary(m: BatchedMachine, cfg: SimConfig, pg, fc) -> int:
    """Index of the first state-changing event in the code slice (len(fc)
    if none): hard boundaries (code 7), predicted write-log fills, and
    predicted page promotions."""
    b = fc.shape[0]
    am = int(fc.argmax())
    if fc[am] == 7:
        b = am
        if b == 0:
            return 0
        fc = fc[:b]
    log = m.log
    if log is not None:
        # each NEW-pair write (code 4) adds one entry; only worth the exact
        # scan when the active buffer could conceivably fill in this chunk
        headroom = log.cap - log.active_n
        if headroom <= b:
            lvl = np.cumsum(fc == np.int8(4))
            if int(lvl[-1]) >= headroom:
                b = min(b, int(np.searchsorted(lvl, headroom)))
                if b == 0:
                    return 0
                fc = fc[:b]
    if m._counting:
        counted = fc >= 2  # every non-host fast event reaches _maybe_promote
        cidx = np.flatnonzero(counted)
        if cidx.size:
            cp = pg[cidx]
            acc_cp = m.acc_count.arr[cp]
            # promotion needs a cache-resident page whose counter crosses
            # the threshold; cheap prescreen before the exact ranking
            resident = m.cache.arr[cp]
            maybe = resident & (acc_cp + cidx.size >= cfg.promote_threshold)
            if maybe.any():
                order = np.argsort(cp, kind="stable")
                sp = cp[order]
                newgrp = np.empty(sp.size, bool)
                newgrp[0] = True
                np.not_equal(sp[1:], sp[:-1], out=newgrp[1:])
                idx = np.arange(sp.size)
                grp_start = np.where(newgrp, idx, 0)
                np.maximum.accumulate(grp_start, out=grp_start)
                occ = np.empty(sp.size, np.int64)
                occ[order] = idx - grp_start
                cand = (acc_cp + occ + 1 >= cfg.promote_threshold) & resident
                if cand.any():
                    b = min(b, int(cidx[cand.argmax()]))
    return b


def _apply_prefix(m: BatchedMachine, cfg: SimConfig, th: Thread,
                  i: int, b: int, t: float, pg, ln, codes) -> float:
    """Retire events [i, i+b) of the thread's trace in bulk. All are
    state-stable under the snapshot; pg/ln/codes are chunk-local views."""
    st = m.stats
    fc = codes[:b]
    cnt = np.bincount(fc, minlength=8).tolist()
    n_hr, n_hw, n_log, n_cr, n_w4, n_w5, n_cw = cnt[:7]
    lats = m._lat_lut8[fc]
    # ONE cumsum replays all four sequential float chains of the reference
    # (`t += gap; t += lat` interleaved; `lat_sum += lat`; `lat_host += lat`
    # on host events; `lat_hit += lat` on the rest). Unused slots hold +0.0,
    # and IEEE x + 0.0 == x exactly, so each row reproduces the reference's
    # left-to-right addition order bit-for-bit.
    buf = np.zeros((4, 2 * b + 1))
    buf[:, 0] = (t, st.lat_sum, st.lat_host, st.lat_hit)
    buf[0, 1::2] = th.gap64[i:i + b]
    buf[:2, 2::2] = lats
    nh = n_hr + n_hw
    hostm = None
    if nh == b:
        buf[2, 2::2] = lats
    elif nh:
        hostm = fc < 2
        buf[2, 2::2] = lats * hostm
        buf[3, 2::2] = lats * ~hostm
    else:
        buf[3, 2::2] = lats
    t, st.lat_sum, st.lat_host, st.lat_hit = buf.cumsum(axis=1)[:, -1].tolist()
    # counters
    st.n += b
    st.host_r += n_hr
    st.host_w += n_hw
    st.hit_log += n_log
    st.hit_cache += n_cr
    st.ssd_w += n_w4 + n_w5 + n_cw
    if cfg.dram_only:
        return t
    # lazy-but-exact state application
    fpg = pg[:b]
    if nh:
        move = m.host.move_to_end
        hpg = fpg if nh == b else fpg[hostm]
        for p in _last_occurrence_order(hpg):
            move(p)
    if n_cr or n_cw:  # cache LRU (read hits + Base-CSSD write hits)
        touch = fc == 3 if not n_cw else (fc == 3) | (fc == 6)
        m.cache.touch_many(_last_occurrence_order(fpg[touch]))
    if n_cw:
        mark = m.cache.mark_dirty
        for p in set(fpg[fc == 6].tolist()):
            mark(p)
    if n_w4:
        wm = fc == 4
        m.log.bulk_append_new(fpg[wm], ln[:b][wm])
    if m._counting and nh != b:
        cpg = fpg if nh == 0 else fpg[~hostm]
        if cpg.size > 1024:  # bincount amortizes its page_space allocation
            m.acc_count.arr += np.bincount(cpg, minlength=m.page_space)
        else:
            np.add.at(m.acc_count.arr, cpg, 1)
    return t


def _inline_span(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                 wslots, i: int, stop: int):
    """Exact per-event replay tuned for boundary-dense stretches.

    Trace columns are native Python lists (no per-event NumPy scalar
    boxing). Every serve() case is transcribed with identical operation
    order — including misses, write-log fills (direct _compact call) and
    promotions (direct _maybe_promote call, which also keeps stochastic
    tpp/astriflash policies exact: the RNG stream is consumed in the same
    per-event order as the reference). Only the coordinated-context-switch
    read miss still goes through serve(), whose trigger/park logic ends
    the quantum anyway. Returns (i, t, blocked).
    """
    pages, lines, writes, gaps = m._columns(th)
    st = m.stats
    serve = m.serve
    maybe_promote = m._maybe_promote
    compact = m._compact
    host = m.host
    move_host = host.move_to_end
    cache = m.cache
    csets = cache.sets
    nsets = cache.n_sets
    log = m.log
    if log is not None:
        log_active = log.active
        log_cap = log.cap
        # memoryview: python-int scalar get/set is ~4x cheaper than NumPy
        # scalar indexing; writes go through to the shared array
        logbits = memoryview(log.bits)
        an = log.active_n  # hoisted; written back around compactions/serve
    promoting = cfg.enable_promotion
    skybyte_count = m._counting  # skybyte policy: cheap threshold precheck
    acc = memoryview(m.acc_count.arr) if skybyte_count else None
    promo_thr = cfg.promote_threshold
    lat_host = cfg.host_dram_ns
    base = cfg.cxl_protocol_ns
    cache_idx = cfg.cache_index_ns
    dram = cfg.ssd_dram_ns
    lat_log = base + cfg.log_index_ns + dram
    lat_cache = base + cache_idx + dram
    ctx_ns = cfg.ctx_switch_ns
    # miss machinery (write-allocate fills, eviction writebacks): misses
    # mutate cache membership but are O(1) dict/list/channel ops — in
    # write-heavy workloads they are ~20% of all events, too frequent to
    # pay full serve() dispatch for
    channels_read = m.channels.read
    channels_write = m.channels.write
    on_flash_write = m.ftl.on_flash_write
    cache_insert = cache.insert
    max_out = cfg.max_outstanding
    ctx_on = cfg.enable_ctx_switch
    # local accumulators: same sequential add order as _record, flushed on exit
    host_r = host_w = hit_log_n = hit_cache_n = miss_n = ssd_w_n = 0
    slow_n = bnd_n = k = 0
    lat_sum = st.lat_sum
    lat_host_acc = st.lat_host
    lat_hit_acc = st.lat_hit
    lat_miss_acc = st.lat_miss
    blocked = False
    for p, l, w, g in zip(pages[i:stop], lines[i:stop], writes[i:stop],
                          gaps[i:stop]):
        t += g
        k += 1
        if p in host:
            move_host(p)
            if w:
                host_w += 1
            else:
                host_r += 1
            lat_sum += lat_host
            lat_host_acc += lat_host
            t += lat_host
            continue
        if w:
            if log is not None:
                # cacheline write log append (serve(): append -> compact
                # if full -> promote)
                e = log_active.get(p)
                if e is None or l not in e:
                    if e is None:
                        e = log_active[p] = {}
                    e[l] = True
                    # no epoch bump: cached codes absorb new lines through
                    # the per-chunk log overlay, not page repair
                    logbits[p] = logbits[p] | (1 << l)
                    an += 1
                    if an >= log_cap:  # filled: drain the old buffer
                        log.active_n = an
                        compact(t)
                        log_active = log.active
                        an = log.active_n
                        bnd_n += 1
                lat = lat_log
            else:
                s = csets[p % nsets]
                d = s.get(p)
                if d is not None:
                    s.move_to_end(p)
                    if not d:
                        s[p] = True  # mark_dirty
                    lat = lat_cache
                else:
                    # Base-CSSD write miss: posted store, background page
                    # fetch in a write slot (transcribed from serve())
                    stall = 0.0
                    if len(wslots) >= max_out:
                        oldest = min(wslots)
                        wslots.remove(oldest)
                        if oldest > t:
                            stall = oldest - t
                    wslots.append(channels_read(p, t + stall))
                    ev = cache_insert(p, True)
                    if ev is not None and ev[1]:
                        channels_write(ev[0], t)
                        on_flash_write(t)
                        st.flash_write_pages += 1
                    bnd_n += 1
                    lat = stall + base + cache_idx + dram
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr and csets[p % nsets].get(p) is not None:
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:  # tpp / astriflash: exact per-event RNG order
                    maybe_promote(p, t)
            ssd_w_n += 1
            lat_sum += lat
            lat_hit_acc += lat
            t += lat
            continue
        # ---- read ----
        if log is not None:
            e = log_active.get(p)
            if e is not None and l in e:
                if promoting:
                    if skybyte_count:
                        c = acc[p] + 1
                        if c >= promo_thr and csets[p % nsets].get(p) is not None:
                            maybe_promote(p, t)
                            bnd_n += 1
                        else:
                            acc[p] = c
                    else:
                        maybe_promote(p, t)
                hit_log_n += 1
                lat_sum += lat_log
                lat_hit_acc += lat_log
                t += lat_log
                continue
        s = csets[p % nsets]
        d = s.get(p)
        if d is not None:
            s.move_to_end(p)
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # resident -> promotion fires
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    maybe_promote(p, t)
            hit_cache_n += 1
            lat_sum += lat_cache
            lat_hit_acc += lat_cache
            t += lat_cache
            continue
        if not ctx_on:
            # flash read miss (transcribed from serve())
            done = channels_read(p, t)
            ev = cache_insert(p, False)
            if ev is not None and ev[1]:
                channels_write(ev[0], t)
                on_flash_write(t)
                st.flash_write_pages += 1
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # just inserted -> resident
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    maybe_promote(p, t)
            bnd_n += 1
            lat = (done - t) + base + cache_idx + dram
            miss_n += 1
            lat_sum += lat
            lat_miss_acc += lat
            t += lat
            continue
        # ---- coordinated-context-switch read miss: serve() decides the
        # trigger and parks the thread (gap already charged) ----
        slow_n += 1
        if log is not None:
            log.active_n = an
        lat, blocked_until, scls = serve(p, l, w, t, wslots)
        if log is not None:
            log_active = log.active  # compaction inside serve swaps buffers
            an = log.active_n
        if blocked_until is not None:
            th.ready = blocked_until
            th.replay = True
            t += ctx_ns
            k -= 1  # squashed access: replayed later, not retired now
            blocked = True
            break
        # host/log/cache were checked above, so this can only be a flash
        # miss the estimator chose not to switch on
        t += lat
        lat_sum += lat
        miss_n += 1
        lat_miss_acc += lat
    if log is not None:
        log.active_n = an
    if k:
        m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
    st.n += k
    st.host_r += host_r
    st.host_w += host_w
    st.hit_log += hit_log_n
    st.hit_cache += hit_cache_n
    st.miss_flash += miss_n
    st.ssd_w += ssd_w_n
    st.lat_sum = lat_sum
    st.lat_host = lat_host_acc
    st.lat_hit = lat_hit_acc
    st.lat_miss = lat_miss_acc
    # k counts retired events; on a block the squashed access sits at i + k
    # and is replayed when the thread wakes (same as the reference loop)
    return i + k, t, blocked


def _classify_few(m: BatchedMachine, th: Thread, cc: _ClsCache,
                  pos) -> None:
    """Scalar-path re-classification of a few ascending trace positions
    (same semantics as _classify_positions, via the dense mirrors)."""
    pages, lines, writes, _ = m._columns(th)
    hostv = m.host._mv
    cachev = m.cache._mv
    log = m.log
    bits = memoryview(log.bits) if log is not None else None
    codes_mv = memoryview(cc.codes)
    seen = set()
    for x in pos.tolist():
        p = pages[x]
        w = writes[x]
        if hostv[p]:
            codes_mv[x] = 1 if w else 0
            continue
        if bits is None:
            codes_mv[x] = (6 if w else 3) if cachev[p] else 7
            continue
        l = lines[x]
        pr = p * 64 + l
        present = (bits[p] >> l) & 1 or pr in seen
        if w:
            if present:
                codes_mv[x] = 5
            else:
                codes_mv[x] = 4
                seen.add(pr)
        elif present:
            codes_mv[x] = 2
        else:
            codes_mv[x] = 3 if cachev[p] else 7


def _fold_boundary(m: BatchedMachine, cfg: SimConfig, th: Thread,
                   cc: _ClsCache, i: int) -> None:
    """Fold the pages mutated by the boundary event just executed (machine
    journal) back into the live cached range, then advance the stamp.

    Advancing the stamp here is sound because quanta are serial: between
    the quantum-entry validation and now, the ONLY state mutations are this
    thread's own boundary events, and their pages are exactly the journal.
    Folding in place keeps the common ctx-switch cycle — miss on page p,
    insert p, evict q, park — from failing the next validation: p is
    usually re-accessed immediately (spatial runs)."""
    jl = m.journal
    if jl:
        if len(jl) <= 24:
            CACHE_STATS["folds"] += 1
            pgr = th.page[i:cc.hi]
            mask = pgr == jl[0]
            for p in jl[1:]:
                mask |= pgr == p
            pos = np.flatnonzero(mask)
            if pos.size:
                pos += i
                if pos.size <= 24:
                    # scalar re-classification: a handful of positions is
                    # not worth ~20 NumPy dispatches
                    _classify_few(m, th, cc, pos)
                else:
                    cc.codes[pos] = _classify_positions(
                        m, cfg, th.page[pos], th.line[pos], th.write[pos])
        else:  # flood (compaction drained the log): reclassify wholesale
            _refresh_cache(m, cfg, th, cc, i, m.chunk)
        jl.clear()
    cc.stamp = m.epoch_clock


def batched_quantum(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                    wslots) -> float:
    """Run one scheduling quantum with the batched engine. Semantically
    identical to simulator._reference_quantum."""
    i, n = th.i, th.n
    if th.replay:
        i, t = _replay_prologue(m, cfg, th, t)
    m.journal.clear()  # only this quantum's boundary bumps matter
    blocked = False
    cc = None
    min_run = m._min_run
    use_cache = m._use_cache
    while i < n and not blocked:
        if m.runlen < min_run or m._inline_only:
            # boundary-dense stretch: per-event inline replay beats even a
            # pre-classified vector pass (repairing the cache at every
            # boundary would dominate); the span reports observed run
            # lengths back into the EWMA so the engine re-vectorizes when
            # runs lengthen again
            cc = None
            i, t, blocked = _inline_span(m, cfg, th, t, wslots, i,
                                         min(i + _SPAN, n))
            continue
        j = min(i + m.chunk, n)
        if use_cache:
            if cc is None:
                cc = m._caches.get(th.tid)
                if cc is None:
                    cc = _ClsCache(n)
                    m._caches[th.tid] = cc
                if i < cc.lo or i >= cc.hi:
                    CACHE_STATS["builds"] += 1
                    _refresh_cache(m, cfg, th, cc, i, j - i)
                else:
                    # re-entry validation: one epoch gather over the
                    # remaining range decides whether any of its pages
                    # changed membership since the stamp — usually not,
                    # so the whole quantum consumes cached codes as-is
                    CACHE_STATS["checks"] += 1
                    if int(m.page_epoch[th.page[i:cc.hi]].max()) > cc.stamp:
                        CACHE_STATS["repairs"] += 1
                        _refresh_cache(m, cfg, th, cc, i, j - i)
                    else:
                        CACHE_STATS["clean"] += 1
                cc.stamp = m.epoch_clock
                m.journal.clear()
            if j > cc.hi:  # chunk overruns the (validated) range
                CACHE_STATS["builds"] += 1
                _refresh_cache(m, cfg, th, cc, i, j - i)
            codes = cc.codes[i:j]
            pg = th.page[i:j]
            ln = th.line[i:j]
        else:
            pg = th.page[i:j]
            ln = th.line[i:j]
            codes = _classify_positions(m, cfg, pg, ln, th.write[i:j])
        b = _next_boundary(m, cfg, pg, codes)
        if b > 0:
            if use_cache and m.log is not None:
                _log_overlay(m, th, i, b, pg, ln, codes)
            t = _apply_prefix(m, cfg, th, i, b, t, pg, ln, codes)
            i += b
        if b < pg.shape[0]:  # boundary inside the chunk
            m.runlen += 0.25 * (b - m.runlen)
            # exact slow path for the state-changing event
            t = t + th.gap64[i]
            pgb = int(pg[b])
            wrb = bool(th.write[i])
            if cc is not None and not wrb and cfg.enable_ctx_switch \
                    and codes[b] == 7:
                # transcribed coordinated-ctx read-miss path (the hottest
                # boundary by far): the epoch validation proves pgb is
                # neither host- nor cache-resident, so only the
                # (append-monotone) write log needs a live probe — the
                # operation order below is serve()'s, to the letter
                log = m.log
                e = log.active.get(pgb) if log is not None else None
                if e is not None and int(ln[b]) in e:
                    # line arrived since classification: an exact log hit
                    m._maybe_promote(pgb, t)
                    lat = m._lat_log
                    t += lat
                    _record(m.stats, "hit_log", lat)
                    i += 1
                else:
                    est = m.channels.estimate(pgb, t)
                    done = m.channels.read(pgb, t)
                    ev = m.cache.insert(pgb, False)
                    m._handle_evict(ev, t)
                    if est > cfg.ctx_threshold_ns:
                        m.stats.ctx_switches += 1
                        m._maybe_promote(pgb, t)
                        th.ready = done
                        th.replay = True
                        t += cfg.ctx_switch_ns
                        blocked = True
                    else:
                        m._maybe_promote(pgb, t)
                        # same left-to-right addition order as serve()
                        lat = (done - t) + cfg.cxl_protocol_ns \
                            + cfg.cache_index_ns + cfg.ssd_dram_ns
                        t += lat
                        _record(m.stats, "miss_flash", lat)
                        i += 1
            elif cc is not None and wrb and m.log is None and codes[b] == 7:
                # transcribed Base-CSSD write miss (posted store, background
                # page fetch in a write slot) — serve()'s order to the letter
                stall = 0.0
                if len(wslots) >= cfg.max_outstanding:
                    oldest = min(wslots)
                    wslots.remove(oldest)
                    if oldest > t:
                        stall = oldest - t
                wslots.append(m.channels.read(pgb, t + stall))
                ev = m.cache.insert(pgb, True)
                m._handle_evict(ev, t)
                m._maybe_promote(pgb, t)
                lat = stall + cfg.cxl_protocol_ns + cfg.cache_index_ns \
                    + cfg.ssd_dram_ns
                t += lat
                _record(m.stats, "ssd_w", lat)
                i += 1
            else:
                lat, blocked_until, scls = m.serve(pgb, int(ln[b]), wrb,
                                                   t, wslots)
                if blocked_until is not None:
                    th.ready = blocked_until
                    th.replay = True
                    t += cfg.ctx_switch_ns
                    blocked = True
                else:
                    t += lat
                    _record(m.stats, scls, lat)
                    i += 1
            if cc is not None:
                _fold_boundary(m, cfg, th, cc, i)
            m.chunk = max(_CHUNK_FLOOR, min(_CHUNK_MAX, 2 * b + 32))
        else:
            m.chunk = min(_CHUNK_MAX, m.chunk * 2)
    th.i = i
    return t
