"""Vectorized batched replay engine — the simulator's fast path.

The reference engine in simulator.py retires one request per Python
iteration (~100-250k req/s). This engine processes each scheduling quantum
in structure-of-arrays batches instead. Two cooperating fast paths cover
the run-length spectrum:

  * **Vector chunks** — a NumPy classification pass over the next chunk of
    the thread's trace resolves runs of *state-stable* accesses in bulk
    (host-DRAM hits, write-log hits, data-cache hits, logged writes) and
    locates the first *state-changing boundary*: flash misses (reads and
    Base-CSSD write misses — channel timing, fills/evictions, GC, context
    switches), write-log fills (compaction), and page promotions. The
    whole prefix is retired with a handful of array ops; only the boundary
    event runs the exact per-event path (the unmodified Machine.serve).
  * **Inline spans** — when observed fast-run lengths drop below the
    vectorization break-even (~200 events on a typical box: each NumPy
    call costs ~1-8 us of dispatch overhead regardless of chunk size), the
    engine switches to a tuned per-event loop: trace columns converted to
    native Python lists once per thread, serve()'s state-stable cases
    inlined with *identical* operation order, and the full serve() only at
    state-changing events. This floors the engine at ~4-8x the reference
    loop even in boundary-dense phases (context-switch-heavy variants cap
    quanta at ~1/miss-rate events, so per-quantum vector overhead cannot
    amortize there).

Exactness contract (enforced by tests/test_engine.py): for the same seed
the batched engine produces *identical* results to the reference engine —
integer counters bit-equal, float accumulators bit-equal as well because
bulk time/latency accumulation replays the reference's sequential
left-to-right addition order (np.cumsum chains in the vector path, local
Python accumulators in the inline path).

How exactness is kept while batching:

  * Dense per-page mirrors of the device state (host-DRAM membership, data
    cache membership, a 64-bit line bitmask per page for the write log, and
    per-page promotion counters) enable O(chunk) NumPy membership passes.
    The mirrors are maintained by thin shadow subclasses of the ssd.py
    structures, so the exact slow path keeps them in sync for free.
  * Boundary detection is *predictive*: log-fill positions come from a
    cumulative count of first-occurrence new (page, line) pairs, promotion
    positions from per-page running access counts vs the threshold. The
    first boundary ends the fast prefix; everything before it is provably
    state-stable under the snapshot.
  * Within-chunk store-to-load forwarding: a read of a (page, line) pair
    whose write appears *earlier in the same chunk* is reclassified as a
    write-log hit (the reference sees the appended line by then).
  * LRU state is applied lazily but exactly: within a boundary-free prefix,
    host/cache LRU order only interacts with itself, so replaying one
    move-to-end per touched page in last-occurrence order yields the same
    final recency order as the reference's per-event touches.

Stochastic promotion policies ("tpp" consumes RNG per access,
"astriflash" promotes on every cache-resident touch) leave no usable
state-stable vector fast path; they are pinned to the inline span, whose
per-event order keeps even the RNG stream exact.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.configs.base import SimConfig
from repro.core.simulator import Machine, Thread, _record, _replay_prologue
from repro.core.ssd import DataCache, WriteLog

# Vectorization break-even: below this expected fast-run length the inline
# per-event span loop beats per-chunk NumPy dispatch overhead.
_VEC_MIN = 192
_CHUNK_MAX = 8192
# Events to replay inline before re-probing vectorization.
_SPAN = 1024


def supported(cfg: SimConfig) -> bool:
    """Whether the batched engine reproduces this config exactly.

    Always true today: stochastic promotion policies (tpp/astriflash) are
    handled by the inline span, which consumes the RNG in the reference's
    per-event order; only the vector path is disabled for them (see
    BatchedMachine._inline_only). Kept as an explicit hook for future
    configs that might need the reference loop.
    """
    return True


class _ArrayCounts:
    """Dense per-page promotion counters, API-compatible with the dict
    Machine.acc_count (only .get and item assignment are used)."""

    __slots__ = ("arr",)

    def __init__(self, page_space: int):
        self.arr = np.zeros(page_space, np.int64)

    def get(self, page: int, default: int = 0) -> int:
        return int(self.arr[page])

    def __setitem__(self, page: int, value: int) -> None:
        self.arr[page] = value


class _ShadowHost(OrderedDict):
    """Host-DRAM LRU with a dense membership mirror. Scalar mirror writes
    go through a memoryview (~4x cheaper than NumPy scalar indexing); the
    ndarray view is what the vector path fancy-indexes."""

    def __init__(self, page_space: int):
        super().__init__()
        self.arr = np.zeros(page_space, bool)
        self._mv = memoryview(self.arr)

    def __setitem__(self, page, value) -> None:
        super().__setitem__(page, value)
        self._mv[page] = True

    def popitem(self, last: bool = True):
        page, value = super().popitem(last)
        self._mv[page] = False
        return page, value


class _ShadowCache(DataCache):
    """DataCache with a dense membership mirror (memoryview for scalar
    writes, ndarray for the vector path's bulk reads)."""

    def __init__(self, cfg: SimConfig, page_space: int):
        super().__init__(cfg)
        self.arr = np.zeros(page_space, bool)
        self._mv = memoryview(self.arr)

    def insert(self, page, dirty):
        ev = super().insert(page, dirty)
        self._mv[page] = True
        if ev is not None:
            self._mv[ev[0]] = False
        return ev

    def remove(self, page) -> None:
        super().remove(page)
        self._mv[page] = False


class _ShadowLog(WriteLog):
    """WriteLog with a per-page 64-bit line-presence bitmask mirror of the
    active buffer (the old buffer is only non-empty inside _compact, which
    never overlaps the fast path)."""

    def __init__(self, cfg: SimConfig, page_space: int):
        super().__init__(cfg)
        self.bits = np.zeros(page_space, np.uint64)

    def append(self, page, line):
        self.bits[page] |= np.uint64(1 << line)
        return super().append(page, line)

    def bulk_append_new(self, pages: np.ndarray, lines: np.ndarray) -> None:
        # bitwise_or.at: pages may repeat within a batch (several new lines
        # of one page); plain fancy-index |= would drop all but one OR
        np.bitwise_or.at(self.bits, pages, np.uint64(1) << lines.astype(np.uint64))
        super().bulk_append_new(pages, lines)

    def swap_for_compaction(self):
        self.bits[:] = 0
        return super().swap_for_compaction()


class BatchedMachine(Machine):
    """Machine whose device structures carry dense NumPy mirrors so whole
    chunks of the trace can be classified without per-event Python."""

    def __init__(self, cfg: SimConfig, seed: int, page_space: int):
        super().__init__(cfg, seed)
        self.page_space = page_space
        self.cache = _ShadowCache(cfg, page_space)
        if cfg.enable_write_log:
            self.log = _ShadowLog(cfg, page_space)
        self.host = _ShadowHost(page_space)
        self.acc_count = _ArrayCounts(page_space)
        # stochastic promotion consumes RNG per access: only the strictly
        # per-event inline span preserves the draw order
        self._inline_only = cfg.enable_promotion and cfg.promo_policy != "skybyte"
        self.chunk = 512  # adaptive: grows on clean chunks, shrinks at boundaries
        # EWMA of fast-run length (events between state-changing boundaries);
        # decides vector chunks vs the inline span loop. Start optimistic so
        # boundary-free configs (dram-only) stay vectorized from event one.
        self.runlen = float(_VEC_MIN)
        self._cols = {}  # tid -> native-list trace columns (inline span path)
        # fast-path latency constants — same expressions as Machine.serve
        base = cfg.cxl_protocol_ns
        lat_host = cfg.host_dram_ns
        lat_log = base + cfg.log_index_ns + cfg.ssd_dram_ns
        lat_cache = base + cfg.cache_index_ns + cfg.ssd_dram_ns
        # class codes: 0 host hit, 1 log hit (read), 2 cache hit (read),
        # 3 logged write, 4 Base-CSSD write hit; -1 = boundary (slow path)
        self._lat_lut = np.array([lat_host, lat_log, lat_cache, lat_log, lat_cache])
        self._counting = cfg.enable_promotion and cfg.promo_policy == "skybyte"

    def _columns(self, th: Thread):
        cols = self._cols.get(th.tid)
        if cols is None:
            cols = (th.page.tolist(), th.line.tolist(), th.write.tolist(),
                    th.gap64.tolist())
            self._cols[th.tid] = cols
        return cols


def _chain_sum(init: float, vals: np.ndarray) -> float:
    """Sequential left-to-right float accumulation: init + v0 + v1 + ...
    in the exact association order the reference's `acc += v` loop uses."""
    buf = np.empty(vals.size + 1)
    buf[0] = init
    buf[1:] = vals
    return np.cumsum(buf)[-1]


def _last_occurrence_order(pages: np.ndarray):
    """Unique pages ordered by their LAST occurrence. Applying one
    move-to-end per page in this order reproduces the final LRU order of
    the reference's per-event touches."""
    # dict.fromkeys keeps first-seen order; feeding the reversed sequence
    # makes that last-seen order, reversed back to ascending position
    d = dict.fromkeys(reversed(pages.tolist()))
    return reversed(d)


def _classify(m: BatchedMachine, cfg: SimConfig, pg, ln, wr):
    """Class codes for a chunk against the current state snapshot, plus the
    line-presence mask (for the log bulk append)."""
    k = len(pg)
    if cfg.dram_only:
        return np.zeros(k, np.int8), None
    hostm = m.host.arr[pg]
    cachem = m.cache.arr[pg]
    if m.log is not None:
        linem = (m.log.bits[pg] >> ln.astype(np.uint64)) & np.uint64(1) != 0
        cls_r = np.where(linem, np.int8(1), np.where(cachem, np.int8(2), np.int8(-1)))
        cls = np.where(hostm, np.int8(0), np.where(wr, np.int8(3), cls_r)).astype(np.int8)
        _forward_log_reads(pg, ln, wr, cls)
    else:
        linem = None
        cls_r = np.where(cachem, np.int8(2), np.int8(-1))
        cls_w = np.where(cachem, np.int8(4), np.int8(-1))
        cls = np.where(hostm, np.int8(0), np.where(wr, cls_w, cls_r)).astype(np.int8)
    return cls, linem


def _forward_log_reads(pg, ln, wr, cls) -> None:
    """Store-to-load forwarding within a chunk: a read of a (page, line)
    pair first *written* at an earlier chunk position sees the appended
    line in the write log — reclassify it from cache-hit/miss to log hit,
    exactly as the reference's log.lookup would."""
    widx = np.flatnonzero(cls == 3)
    if not widx.size:
        return
    ridx = np.flatnonzero((cls == 2) | (cls == -1) & ~wr)
    if not ridx.size:
        return
    wpairs = pg[widx] * 64 + ln[widx]
    order = np.argsort(wpairs, kind="stable")
    sw = wpairs[order]
    keep = np.empty(sw.size, bool)
    keep[0] = True
    np.not_equal(sw[1:], sw[:-1], out=keep[1:])
    upairs = sw[keep]
    upos = widx[order][keep]  # earliest write position per pair
    rpairs = pg[ridx] * 64 + ln[ridx]
    loc = np.searchsorted(upairs, rpairs)
    loc[loc == upairs.size] = 0  # clamp; mismatch check below rejects
    fwd = (upairs[loc] == rpairs) & (upos[loc] < ridx)
    cls[ridx[fwd]] = 1


def _first_boundary(m: BatchedMachine, cfg: SimConfig, pg, ln, cls, linem) -> int:
    """Index of the first state-changing event in the chunk (len(pg) if
    none): hard boundaries (cls == -1), predicted write-log fills, and
    predicted page promotions."""
    b = len(pg)
    hard = cls == -1
    if hard.any():
        b = int(hard.argmax())
    if m.log is not None and b > 0:
        wmask = cls[:b] == 3
        widx = np.flatnonzero(wmask)
        # each write adds at most one entry: only worth the exact count
        # when the active buffer could conceivably fill inside the prefix
        if widx.size and m.log.active_n + widx.size >= m.log.cap:
            pairs = pg[widx] * 64 + ln[widx]
            _, first = np.unique(pairs, return_index=True)
            isnew = np.zeros(widx.size, bool)
            fresh = first[~linem[widx][first]]  # pair not in the active log yet
            isnew[fresh] = True
            level = m.log.active_n + np.cumsum(isnew)
            fill = level >= m.log.cap
            if fill.any():
                b = min(b, int(widx[fill.argmax()]))
    if m._counting and b > 0:
        counted = cls[:b] > 0  # every non-host fast event reaches _maybe_promote
        cidx = np.flatnonzero(counted)
        if cidx.size:
            cp = pg[cidx]
            # promotion needs a cache-resident page whose counter crosses
            # the threshold; cheap prescreen before the exact ranking
            resident = m.cache.arr[cp]
            maybe = resident & (m.acc_count.arr[cp] + cidx.size >= cfg.promote_threshold)
            if maybe.any():
                order = np.argsort(cp, kind="stable")
                sp = cp[order]
                newgrp = np.empty(sp.size, bool)
                newgrp[0] = True
                np.not_equal(sp[1:], sp[:-1], out=newgrp[1:])
                idx = np.arange(sp.size)
                grp_start = np.where(newgrp, idx, 0)
                np.maximum.accumulate(grp_start, out=grp_start)
                occ = np.empty(sp.size, np.int64)
                occ[order] = idx - grp_start
                projected = m.acc_count.arr[cp] + occ + 1
                cand = (projected >= cfg.promote_threshold) & resident
                if cand.any():
                    b = min(b, int(cidx[cand.argmax()]))
    return b


def _apply_fast_prefix(m: BatchedMachine, cfg: SimConfig, th: Thread,
                       i: int, b: int, t: float, pg, ln, wr, cls) -> float:
    """Retire events [i, i+b) of the thread's trace in bulk. All are
    state-stable under the snapshot; cls is a chunk-local view."""
    st = m.stats
    fc = cls[:b]
    fpg = pg[:b]
    lats = m._lat_lut[fc]
    # time: replay the reference's `t += gap; t += lat` sequence exactly
    buf = np.empty(2 * b + 1)
    buf[0] = t
    buf[1::2] = th.gap64[i:i + b]
    buf[2::2] = lats
    t = np.cumsum(buf)[-1]
    # counters
    hostc = fc == 0
    st.n += b
    n_host = int(np.count_nonzero(hostc))
    if n_host:
        n_hw = int(np.count_nonzero(hostc & wr[:b]))
        st.host_r += n_host - n_hw
        st.host_w += n_hw
    st.hit_log += int(np.count_nonzero(fc == 1))
    st.hit_cache += int(np.count_nonzero(fc == 2))
    st.ssd_w += int(np.count_nonzero(fc >= 3))
    st.lat_sum = _chain_sum(st.lat_sum, lats)
    if n_host:
        st.lat_host = _chain_sum(st.lat_host, lats[hostc])
    hitm = fc > 0
    if hitm.any():
        st.lat_hit = _chain_sum(st.lat_hit, lats[hitm])
    if cfg.dram_only:
        return t
    # lazy-but-exact state application
    if n_host:
        move = m.host.move_to_end
        for p in _last_occurrence_order(fpg[hostc]):
            move(p)
    touch = (fc == 2) | (fc == 4)
    if touch.any():  # cache LRU (read hits + Base-CSSD write hits)
        m.cache.touch_many(_last_occurrence_order(fpg[touch]))
    dirty = fc == 4
    if dirty.any():
        mark = m.cache.mark_dirty
        for p in set(fpg[dirty].tolist()):
            mark(p)
    logw = fc == 3
    if logw.any():
        lpg, lln = fpg[logw], ln[:b][logw]
        bits = m.log.bits
        seen = set()
        np_new, nl_new = [], []
        for p, l in zip(lpg.tolist(), lln.tolist()):
            pr = p * 64 + l
            if pr in seen:
                continue
            seen.add(pr)
            if not int(bits[p]) >> l & 1:
                np_new.append(p)
                nl_new.append(l)
        if np_new:
            m.log.bulk_append_new(np.asarray(np_new, np.int64),
                                  np.asarray(nl_new, np.int64))
    if m._counting:
        counted = fc > 0
        if counted.any():
            # per-page totals via a dict (faster than np.add.at dispatch at
            # typical chunk sizes); keys are unique, fancy += is safe
            totals = {}
            for p in fpg[counted].tolist():
                totals[p] = totals.get(p, 0) + 1
            m.acc_count.arr[list(totals)] += list(totals.values())
    return t


def _inline_span(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                 wslots, i: int, stop: int):
    """Exact per-event replay tuned for boundary-dense stretches.

    Trace columns are native Python lists (no per-event NumPy scalar
    boxing). Every serve() case is transcribed with identical operation
    order — including misses, write-log fills (direct _compact call) and
    promotions (direct _maybe_promote call, which also keeps stochastic
    tpp/astriflash policies exact: the RNG stream is consumed in the same
    per-event order as the reference). Only the coordinated-context-switch
    read miss still goes through serve(), whose trigger/park logic ends
    the quantum anyway. Returns (i, t, blocked).
    """
    pages, lines, writes, gaps = m._columns(th)
    st = m.stats
    serve = m.serve
    maybe_promote = m._maybe_promote
    compact = m._compact
    host = m.host
    move_host = host.move_to_end
    cache = m.cache
    csets = cache.sets
    nsets = cache.n_sets
    log = m.log
    if log is not None:
        log_active = log.active
        log_cap = log.cap
        # memoryview: python-int scalar get/set is ~4x cheaper than NumPy
        # scalar indexing; writes go through to the shared array
        logbits = memoryview(log.bits)
        an = log.active_n  # hoisted; written back around compactions/serve
    promoting = cfg.enable_promotion
    skybyte_count = m._counting  # skybyte policy: cheap threshold precheck
    acc = memoryview(m.acc_count.arr) if skybyte_count else None
    promo_thr = cfg.promote_threshold
    lat_host = cfg.host_dram_ns
    base = cfg.cxl_protocol_ns
    cache_idx = cfg.cache_index_ns
    dram = cfg.ssd_dram_ns
    lat_log = base + cfg.log_index_ns + dram
    lat_cache = base + cache_idx + dram
    ctx_ns = cfg.ctx_switch_ns
    # miss machinery (write-allocate fills, eviction writebacks): misses
    # mutate cache membership but are O(1) dict/list/channel ops — in
    # write-heavy workloads they are ~20% of all events, too frequent to
    # pay full serve() dispatch for
    channels_read = m.channels.read
    channels_write = m.channels.write
    on_flash_write = m.ftl.on_flash_write
    cache_insert = cache.insert
    max_out = cfg.max_outstanding
    ctx_on = cfg.enable_ctx_switch
    # local accumulators: same sequential add order as _record, flushed on exit
    host_r = host_w = hit_log_n = hit_cache_n = miss_n = ssd_w_n = 0
    slow_n = bnd_n = k = 0
    lat_sum = st.lat_sum
    lat_host_acc = st.lat_host
    lat_hit_acc = st.lat_hit
    lat_miss_acc = st.lat_miss
    blocked = False
    for p, l, w, g in zip(pages[i:stop], lines[i:stop], writes[i:stop],
                          gaps[i:stop]):
        t += g
        k += 1
        if p in host:
            move_host(p)
            if w:
                host_w += 1
            else:
                host_r += 1
            lat_sum += lat_host
            lat_host_acc += lat_host
            t += lat_host
            continue
        if w:
            if log is not None:
                # cacheline write log append (serve(): append -> compact
                # if full -> promote)
                e = log_active.get(p)
                if e is None or l not in e:
                    if e is None:
                        e = log_active[p] = {}
                    e[l] = True
                    logbits[p] = logbits[p] | (1 << l)
                    an += 1
                    if an >= log_cap:  # filled: drain the old buffer
                        log.active_n = an
                        compact(t)
                        log_active = log.active
                        an = log.active_n
                        bnd_n += 1
                lat = lat_log
            else:
                s = csets[p % nsets]
                d = s.get(p)
                if d is not None:
                    s.move_to_end(p)
                    if not d:
                        s[p] = True  # mark_dirty
                    lat = lat_cache
                else:
                    # Base-CSSD write miss: posted store, background page
                    # fetch in a write slot (transcribed from serve())
                    stall = 0.0
                    if len(wslots) >= max_out:
                        oldest = min(wslots)
                        wslots.remove(oldest)
                        if oldest > t:
                            stall = oldest - t
                    wslots.append(channels_read(p, t + stall))
                    ev = cache_insert(p, True)
                    if ev is not None and ev[1]:
                        channels_write(ev[0], t)
                        on_flash_write(t)
                        st.flash_write_pages += 1
                    bnd_n += 1
                    lat = stall + base + cache_idx + dram
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr and csets[p % nsets].get(p) is not None:
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:  # tpp / astriflash: exact per-event RNG order
                    maybe_promote(p, t)
            ssd_w_n += 1
            lat_sum += lat
            lat_hit_acc += lat
            t += lat
            continue
        # ---- read ----
        if log is not None:
            e = log_active.get(p)
            if e is not None and l in e:
                if promoting:
                    if skybyte_count:
                        c = acc[p] + 1
                        if c >= promo_thr and csets[p % nsets].get(p) is not None:
                            maybe_promote(p, t)
                            bnd_n += 1
                        else:
                            acc[p] = c
                    else:
                        maybe_promote(p, t)
                hit_log_n += 1
                lat_sum += lat_log
                lat_hit_acc += lat_log
                t += lat_log
                continue
        s = csets[p % nsets]
        d = s.get(p)
        if d is not None:
            s.move_to_end(p)
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # resident -> promotion fires
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    maybe_promote(p, t)
            hit_cache_n += 1
            lat_sum += lat_cache
            lat_hit_acc += lat_cache
            t += lat_cache
            continue
        if not ctx_on:
            # flash read miss (transcribed from serve())
            done = channels_read(p, t)
            ev = cache_insert(p, False)
            if ev is not None and ev[1]:
                channels_write(ev[0], t)
                on_flash_write(t)
                st.flash_write_pages += 1
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # just inserted -> resident
                        maybe_promote(p, t)
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    maybe_promote(p, t)
            bnd_n += 1
            lat = (done - t) + base + cache_idx + dram
            miss_n += 1
            lat_sum += lat
            lat_miss_acc += lat
            t += lat
            continue
        # ---- coordinated-context-switch read miss: serve() decides the
        # trigger and parks the thread (gap already charged) ----
        slow_n += 1
        if log is not None:
            log.active_n = an
        lat, blocked_until, scls = serve(p, l, w, t, wslots)
        if log is not None:
            log_active = log.active  # compaction inside serve swaps buffers
            an = log.active_n
        if blocked_until is not None:
            th.ready = blocked_until
            th.replay = True
            t += ctx_ns
            k -= 1  # squashed access: replayed later, not retired now
            blocked = True
            break
        # host/log/cache were checked above, so this can only be a flash
        # miss the estimator chose not to switch on
        t += lat
        lat_sum += lat
        miss_n += 1
        lat_miss_acc += lat
    if log is not None:
        log.active_n = an
    if k:
        m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
    st.n += k
    st.host_r += host_r
    st.host_w += host_w
    st.hit_log += hit_log_n
    st.hit_cache += hit_cache_n
    st.miss_flash += miss_n
    st.ssd_w += ssd_w_n
    st.lat_sum = lat_sum
    st.lat_host = lat_host_acc
    st.lat_hit = lat_hit_acc
    st.lat_miss = lat_miss_acc
    # k counts retired events; on a block the squashed access sits at i + k
    # and is replayed when the thread wakes (same as the reference loop)
    return i + k, t, blocked


def batched_quantum(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                    wslots) -> float:
    """Run one scheduling quantum with the batched engine. Semantically
    identical to simulator._reference_quantum."""
    i, n = th.i, th.n
    if th.replay:
        i, t = _replay_prologue(m, cfg, th, t)
    blocked = False
    while i < n and not blocked:
        if (m.runlen < _VEC_MIN or m._inline_only) and not cfg.dram_only:
            # boundary-dense stretch: inline replay beats per-chunk NumPy
            # dispatch (each array op costs fixed ~1-8us regardless of size);
            # the span reports observed run lengths back into the EWMA so
            # the engine re-vectorizes when runs lengthen again
            i, t, blocked = _inline_span(m, cfg, th, t, wslots, i,
                                         min(i + _SPAN, n))
            continue
        j = min(i + m.chunk, n)
        pg = th.page[i:j]
        ln = th.line[i:j]
        wr = th.write[i:j]
        cls, linem = _classify(m, cfg, pg, ln, wr)
        b = _first_boundary(m, cfg, pg, ln, cls, linem)
        if b > 0:
            t = _apply_fast_prefix(m, cfg, th, i, b, t, pg, ln, wr, cls)
            i += b
        if b < len(pg):  # boundary inside the chunk
            m.runlen += 0.25 * (b - m.runlen)
            # exact slow path for the state-changing event
            t = t + th.gap64[i]
            lat, blocked_until, scls = m.serve(int(pg[b]), int(ln[b]),
                                               bool(wr[b]), t, wslots)
            if blocked_until is not None:
                th.ready = blocked_until
                th.replay = True
                t += cfg.ctx_switch_ns
                blocked = True
            else:
                t += lat
                _record(m.stats, scls, lat)
                i += 1
            m.chunk = max(_VEC_MIN, min(_CHUNK_MAX, 2 * b + 32))
        else:
            m.chunk = min(_CHUNK_MAX, m.chunk * 2)
    th.i = i
    return t
