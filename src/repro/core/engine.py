"""Vectorized batched replay engine — the simulator's fast path.

The reference engine in simulator.py retires one request per Python
iteration (~100-250k req/s). This engine processes each scheduling quantum
in structure-of-arrays batches over the SAME ``DeviceState`` the reference
loop uses (device_state.py) — since the unified-state refactor there are
no engine-private mirrors to keep in sync: the membership arrays, LRU
stamps, log bitmasks, promotion counters and page epochs it classifies
against ARE the device state, mutated through the ssd.py policy views.

  * **Classification cache** — each thread carries a classified *range*
    of its upcoming trace (``SimConfig.cls_cache_window`` events at most),
    produced by one vectorized pass into extended class codes (table
    below). A scheduling quantum then only has to find the next boundary
    (one argmax over the cached codes) and bulk-retire the prefix; the
    range survives across quanta and is re-classified only when the epoch
    check proves it stale or the thread consumes past its end.
  * **Epoch-based page-version repair** — every membership mutation bumps
    a per-page epoch counter (``DeviceState.bump``): cache
    inserts/evictions, host promotions and demotions, and log compactions
    (which invalidate every logged line of the drained buffer at once).
    On quantum re-entry the engine takes the max epoch of the remaining
    range's pages (one gather) and compares it against the range's stamp —
    clean means the codes are provably current for the whole quantum
    (quanta are serial: no other thread can run mid-quantum) and the stamp
    advances; dirty means the range is re-classified from the current
    position in one vector pass. Mid-quantum, the only mutators are this
    thread's own boundary events; the pages they bump are recorded in the
    state's journal and folded back in place (re-classify just their range
    positions), after which the stamp advances again. Log *appends*
    deliberately do not bump epochs (warm write pages are appended to
    constantly by every thread and would keep every cache dirty); line
    presence only grows between compactions, so the prefix about to be
    bulk-applied is instead brought current by a tiny targeted overlay
    (see _log_overlay).
  * **Fused exact accumulators** — the four sequential float chains the
    reference maintains (core time, lat_sum, lat_host, lat_hit) are
    replayed with ONE cumsum over a 4-row buffer whose unused slots are
    zero: IEEE addition of +0.0 is exact, so each row reproduces the
    reference's left-to-right addition order bit-for-bit.
  * **Transcribed boundaries** — every state-changing event (flash read
    misses with fills/evictions/GC, Base-CSSD write misses, write-log
    fills and their compaction drain, predicted promotions/demotions) is
    executed by an exact transcription inside this module, against the
    shared state, in ``Machine.serve()``'s operation order to the letter.
    ``serve()`` itself is never called by this engine — it survives as the
    reference loop's per-event oracle only. Flash service locations are
    resolved from the LIVE l2p mapping at every boundary (``m.loc_of`` /
    the span's inlined block-id derivation): mapping changes only ever
    happen on boundary paths, so the cached classification codes — which
    never encode placement — stay untouched by physical routing.
  * **Inline spans** — when observed fast-run lengths drop below the cache
    break-even (``SimConfig.cls_cache_min_run``; boundary-dense phases
    such as Base-CSSD write storms), the engine switches to the tuned
    per-event loop: every serve() case inlined with *identical* operation
    order.

Extended class codes (int8; one per trace position):

  0 host-DRAM read hit      4 logged write, NEW (page,line) pair
  1 host-DRAM write hit     5 logged write, already-present pair
  2 write-log read hit      6 Base-CSSD cache write hit
  3 data-cache read hit     7 boundary (miss / fill / slow path)

Codes 0-6 are *state-stable*: their device-state effects are closed-form
under a snapshot. Code 7 events run the transcribed slow paths. Write-log
fills and page promotions are *predicted* boundaries found from the cached
codes (cumulative new-pair counts vs the log headroom; per-page running
access counts vs the promotion threshold). Store-to-load forwarding is
encoded at classification time: a read of a (page, line) pair whose first
in-window write precedes it is classified a log hit, which stays correct
across quanta because any other writer of that page bumps its epoch.

Exactness contract (enforced by tests/test_engine.py and
tests/test_engine_cache.py): for the same seed the batched engine — with
the cache on or off, under any churn — produces *identical* results to the
reference engine; integer counters bit-equal, float accumulators bit-equal
as well because bulk accumulation replays the reference's sequential
addition order.

Stochastic promotion policies ("tpp" consumes RNG per access,
"astriflash" promotes on every cache-resident touch) leave no usable
state-stable fast path; they are pinned to the inline span, whose
per-event order keeps even the RNG stream exact.
"""
from __future__ import annotations

import bisect
import heapq
import os

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL
from repro.core.simulator import (Machine, Thread, _advance_idle_cores,
                                  _lat_bin, _record, _run_scheduler)
from repro.core.ssd import TRANSFER_NS

# Vectorization break-even WITHOUT the classification cache: below this
# expected fast-run length the inline per-event span loop beats per-chunk
# NumPy classify + dispatch overhead. (With the cache the break-even is
# SimConfig.cls_cache_min_run; since the unified-state refactor inlined
# the span's miss machinery its default sits AT this threshold — see the
# knob's comment in configs/base.py — and lowering it only pays on boxes
# with cheaper NumPy dispatch than the CI container's ~3.5us.)
_VEC_MIN = 192
_CHUNK_MAX = 8192
_CHUNK_FLOOR = 64
# Events to replay inline before re-probing vectorization.
_SPAN = 1024

# Cross-quantum classification-cache observability (per process; reset by
# simulate() at the start of every batched run). benchmarks/run.py folds
# these into BENCH_sim.json's engine calibration section.
CACHE_STATS = {
    "builds": 0,      # range classifications due to range exhaustion/first use
    "checks": 0,      # quantum re-entry epoch validations of a live range
    "clean": 0,       # validations whose range pages were all unchanged (hits)
    "repairs": 0,     # dirty validations -> range re-classified in place
    "folds": 0,       # boundary-event page sets folded back mid-quantum
    "classified": 0,  # total events classified (amortization denominator)
}


def reset_cache_stats() -> None:
    for k in CACHE_STATS:
        CACHE_STATS[k] = 0


def cache_hit_rate() -> float:
    """Fraction of re-entry validations that consumed cached codes as-is."""
    v = CACHE_STATS["checks"]
    return CACHE_STATS["clean"] / v if v else 0.0


def cache_repair_rate() -> float:
    """Fraction of re-entry validations that re-classified the range."""
    v = CACHE_STATS["checks"]
    return CACHE_STATS["repairs"] / v if v else 0.0


# Fused-engine observability (per process; reset by simulate() alongside
# CACHE_STATS). Tracks which machinery retired each trace event so the
# span-floor trajectory is visible across PRs (BENCH_sim.json calibration
# cells record span_events and fused_frac per cell).
FUSED_STATS = {
    "fused_events": 0,    # retired by the fused cross-thread kernel
    "span_events": 0,     # retired by the scalar fallback span
    "vector_events": 0,   # bulk-retired by the vectorized chunk path
    "stage_rounds": 0,    # cross-thread window staging passes
    "staged_threads": 0,  # thread windows classified across all rounds
}


def reset_fused_stats() -> None:
    for k in FUSED_STATS:
        FUSED_STATS[k] = 0


def fused_fraction(total_events: int) -> float:
    """Fraction of events retired by the fused kernel or the vector path
    (i.e. NOT by the scalar fallback span)."""
    if total_events <= 0:
        return 0.0
    return 1.0 - FUSED_STATS["span_events"] / total_events


def supported(cfg: SimConfig) -> bool:
    """Whether the batched engine reproduces this config exactly.

    Always true today: stochastic promotion policies (tpp/astriflash) are
    handled by the inline span, which consumes the RNG in the reference's
    per-event order; only the vector path is disabled for them (see
    BatchedMachine._inline_only). Kept as an explicit hook for future
    configs that might need the reference loop.
    """
    return True


class _ClsCache:
    """Per-thread cross-quantum classification cache.

    ``codes[lo:hi]`` holds the extended class code of every trace position
    in the cached range, classified against the device state at epoch
    ``stamp``. A chunk whose pages' epochs are all <= stamp consumes the
    codes as-is; anything else re-classifies the range from the current
    position (one vector pass — cheaper than surgically patching pages,
    whose stale sets only grow).

    In the fused scheduler the cache doubles as the *window staging* slot:
    ``sevens`` holds the staged positions of predicted boundaries (code 7)
    inside [lo, hi) and ``sp`` the consumption cursor. Predictions are
    ADVISORY — they only size the fused kernel's slice windows; the kernel
    live-probes every event, so stale predictions cost a re-entry, never
    correctness."""

    __slots__ = ("codes", "lo", "hi", "stamp", "sevens", "sp")

    def __init__(self, n: int):
        self.codes = np.empty(n, np.int8)
        self.lo = 0
        self.hi = 0
        self.stamp = -1
        self.sevens = ()
        self.sp = 0


class BatchedMachine(Machine):
    """Machine plus the batched engine's bookkeeping: per-thread
    classification caches, the adaptive chunk/run-length state, and
    precomputed latency constants. All *device* state lives in the
    inherited ``self.state`` — shared, not mirrored."""

    def __init__(self, cfg: SimConfig, seed: int, page_space: int):
        super().__init__(cfg, seed, page_space)
        self.page_space = self.state.page_space
        # stochastic promotion consumes RNG per access: only the strictly
        # per-event inline span preserves the draw order
        self._inline_only = cfg.enable_promotion and cfg.promo_policy != "skybyte"
        self._use_cache = (cfg.cls_cache and not self._inline_only
                           and not cfg.dram_only)
        self._min_run = cfg.cls_cache_min_run if self._use_cache else _VEC_MIN
        self._window = max(int(cfg.cls_cache_window), 1)
        self._caches: dict = {}  # tid -> _ClsCache
        # Fused-scheduler hooks: run_fused() attaches the thread list so
        # window staging can classify ALL pending threads in one flat
        # vector pass. Staged boundary prediction (code-7 positions sizing
        # the kernel's slice windows) is only meaningful when quanta end
        # early (ctx on) and the no-log classifier can stage ahead — and
        # even then it is OFF by default: on this container classifying a
        # full window costs more than the tighter slices save (the kernel
        # live-probes each event in ~93ns either way; see DESIGN.md).
        # REPRO_FUSED_PREDICT=1 turns it on — it stays bit-exact (window
        # sizing is advisory), so the parity suites cover both settings.
        self._threads = None
        self._predict = (self._use_cache and cfg.enable_ctx_switch
                         and not cfg.enable_write_log
                         and os.environ.get("REPRO_FUSED_PREDICT") == "1")
        self.chunk = 512  # adaptive: grows on clean chunks, shrinks at boundaries
        # EWMA of fast-run length (events between state-changing boundaries);
        # decides vector chunks vs the inline span loop. Start optimistic so
        # boundary-free configs (dram-only) stay vectorized from event one.
        self.runlen = float(_VEC_MIN)
        self._cols = {}  # tid -> native-list trace columns (inline span path)
        # fast-path latency constants — same expressions as Machine.serve
        base = cfg.cxl_protocol_ns
        lat_host = cfg.host_dram_ns
        lat_log = base + cfg.log_index_ns + cfg.ssd_dram_ns
        lat_cache = base + cfg.cache_index_ns + cfg.ssd_dram_ns
        # per extended class code (0-7; boundary gets 0, never used)
        self._lat_lut8 = np.array([lat_host, lat_host, lat_log, lat_cache,
                                   lat_log, lat_log, lat_cache, 0.0])
        self._lat_log = lat_log
        self._lat_cache = lat_cache
        self._counting = cfg.enable_promotion and cfg.promo_policy == "skybyte"
        # Invariant locals of the inline span, packed once: a quantum in a
        # ctx-bound cell is ~50 events, short enough that re-deriving ~35
        # bindings per span call shows up. Mutable identities (log_active,
        # the hoisted fill level / LRU clock) stay per-call.
        ds = self.state
        self._span_env = (
            self._maybe_promote, self._compact, ds.host,
            ds.host.move_to_end, ds.cache_res_mv, ds.cache_dirty_mv,
            ds.cache_stamp_mv, ds.cache_sets, ds.cache_way,
            ds.cache_n_sets, ds.cache_ways, ds.epoch_mv, ds.journal,
            cfg.enable_promotion, self._counting,
            ds.acc._mv if self._counting else None, cfg.promote_threshold,
            cfg.host_dram_ns, base, cfg.cache_index_ns, cfg.ssd_dram_ns,
            lat_log, lat_cache, cfg.ctx_switch_ns, cfg.ctx_threshold_ns,
            ds.chan_bus, ds.chan_die, cfg.n_channels, cfg.flash.read_ns,
            TRANSFER_NS + cfg.flash.read_ns / DIES_PER_CHANNEL,
            self.ftl.on_flash_write,
            cfg.max_outstanding, cfg.enable_ctx_switch,
            memoryview(ds.log_bits) if cfg.enable_write_log else None,
            ds.log_cap,
            # physical service-path routing (None/0 under the legacy
            # backend: the span then uses the logical hash stripe inline).
            # loc_div is the (channel, die) divisor: pp // loc_div is the
            # block id normally (per-die blocks), pp itself under
            # superblock striping (ftl.loc_div — ONE value covers every
            # inlined derivation site with zero new branches).
            ds.flash.l2p_mv if ds.flash is not None else None,
            self.ftl.loc_div if ds.flash is not None else 0,
            ds.gc_die_from, ds.gc_die_until,
            # fault injection / die-level QoS / latency provenance: the
            # bound Channels.read when a FaultModel, QosModel or ObsModel
            # is attached, else None. All three are conflict classes —
            # the span routes affected flash reads through the shared
            # method (retry ladder, outages, scheduled events; GC
            # suspend/resume, read-priority arbitration; per-request
            # component staging) instead of its inlined timing mirror, so
            # both engines consume the identical fault stream /
            # arbitration decisions / attribution stream.
            self.channels.read
            if (self.channels.fault is not None
                or self.channels.qos is not None
                or self.channels.obs is not None) else None,
        )

    def _columns(self, th: Thread):
        cols = self._cols.get(th.tid)
        if cols is None:
            cols = (th.page.tolist(), th.line.tolist(), th.write.tolist(),
                    th.gap64.tolist())
            self._cols[th.tid] = cols
        return cols


def _last_occurrence_order(pages: np.ndarray):
    """Unique pages ordered by their LAST occurrence. Applying one
    move-to-end per page in this order reproduces the final LRU order of
    the reference's per-event touches."""
    # dict.fromkeys keeps first-seen order; feeding the reversed sequence
    # makes that last-seen order, reversed back to ascending position
    d = dict.fromkeys(reversed(pages.tolist()))
    return reversed(d)


def _classify_positions(m: BatchedMachine, cfg: SimConfig, pg, ln, wr,
                        pair_base=None):
    """Extended class codes for a batch of trace events against the current
    state snapshot.

    The batch may be a contiguous trace slice OR any gather of positions,
    as long as same-page events appear in ascending trace order: the
    newness / store-to-load-forwarding logic groups by (page, line) pair,
    and pairs never span pages, so per-page ascending order is the only
    ordering it observes. When the batch concatenates windows of SEVERAL
    threads (fused staging), ``pair_base`` carries a per-event segment
    offset that keeps the (page, line) grouping — and therefore the
    store-to-load forwarding — from leaking across thread boundaries."""
    if cfg.dram_only:
        return wr.astype(np.int8)
    ds = m.state
    k = pg.shape[0]
    hostm = ds.host.arr[pg]
    cachem = ds.cache_res[pg]
    if m.log is None:
        return np.where(
            hostm, wr.astype(np.int8),
            np.where(cachem,
                     np.where(wr, np.int8(6), np.int8(3)),
                     np.int8(7)),
        ).astype(np.int8)
    linem = (ds.log_bits[pg] >> ln.astype(np.uint64)) & np.uint64(1) != 0
    new = np.zeros(k, bool)
    logged = linem
    wmask = wr & ~hostm
    widx = np.flatnonzero(wmask)
    if widx.size:
        if pair_base is None:
            pairs = pg * 64 + ln
        else:
            pairs = (pg + pair_base) * 64 + ln
        wp = pairs[widx]
        order = np.argsort(wp, kind="stable")
        sw = wp[order]
        first = np.empty(sw.size, bool)
        first[0] = True
        np.not_equal(sw[1:], sw[:-1], out=first[1:])
        fidx = widx[order[first]]  # earliest in-batch write per pair
        new[fidx] = ~linem[fidx]
        # forwarding: any event on the pair AFTER its first write sees the
        # appended line (the reference's log.lookup would by then)
        upairs = sw[first]
        loc = np.searchsorted(upairs, pairs)
        loc[loc == upairs.size] = 0  # clamp; mismatch check below rejects
        logged = linem | ((upairs[loc] == pairs) & (fidx[loc] < np.arange(k)))
    wcodes = np.where(new, np.int8(4), np.int8(5))
    rcodes = np.where(logged, np.int8(2),
                      np.where(cachem, np.int8(3), np.int8(7)))
    return np.where(hostm, wr.astype(np.int8),
                    np.where(wr, wcodes, rcodes)).astype(np.int8)


def _refresh_cache(m: BatchedMachine, cfg: SimConfig, th: Thread,
                   cc: _ClsCache, i: int, want: int) -> None:
    """(Re)classify the thread's cached range starting at position i,
    covering at least ``want`` events. The range scales with the adaptive
    chunk (boundary-dense phases keep refreshes cheap, stable phases
    amortize over tens of thousands of events), capped by the
    ``SimConfig.cls_cache_window`` knob."""
    r = min(th.n, i + max(min(4 * m.chunk, m._window), want))
    cc.codes[i:r] = _classify_positions(m, cfg, th.page[i:r], th.line[i:r],
                                        th.write[i:r])
    cc.lo = i
    cc.hi = r
    cc.stamp = m.state.epoch_clock
    if m._predict:  # refresh the advisory boundary predictions too
        cc.sevens = (np.flatnonzero(cc.codes[i:r] == 7) + i).tolist()
        cc.sp = 0
    CACHE_STATS["classified"] += r - i


def _log_overlay(m: BatchedMachine, th: Thread, i: int, b: int,
                 pg, ln, codes) -> None:
    """Fold write-log lines appended since classification into the prefix
    about to be applied. Line presence only grows between compactions
    (which bump epochs and take the repair path), so the only stale code
    that could corrupt bulk application is a cache-read-hit whose line is
    now logged (3 -> 2: the reference checks the log before the cache).
    Stale NEW-pair writes are absorbed by the dup-tolerant bulk append,
    and a read-miss that became a log hit (7) stays a boundary that the
    transcribed slow path resolves exactly."""
    fc = codes[:b]
    aff = np.flatnonzero(fc == 3)
    if aff.size:
        bits = m.state.log_bits
        linem = (bits[pg[aff]] >> ln[aff].astype(np.uint64)) \
            & np.uint64(1) != 0
        if linem.any():
            fc[aff[linem]] = 2


def _next_boundary(m: BatchedMachine, cfg: SimConfig, pg, fc) -> int:
    """Index of the first state-changing event in the code slice (len(fc)
    if none): hard boundaries (code 7), predicted write-log fills, and
    predicted page promotions."""
    b = fc.shape[0]
    am = int(fc.argmax())
    if fc[am] == 7:
        b = am
        if b == 0:
            return 0
        fc = fc[:b]
    ds = m.state
    if m.log is not None:
        # each NEW-pair write (code 4) adds one entry; only worth the exact
        # scan when the active buffer could conceivably fill in this chunk
        headroom = ds.log_cap - ds.log_active_n
        if headroom <= b:
            lvl = np.cumsum(fc == np.int8(4))
            if int(lvl[-1]) >= headroom:
                b = min(b, int(np.searchsorted(lvl, headroom)))
                if b == 0:
                    return 0
                fc = fc[:b]
    if m._counting:
        counted = fc >= 2  # every non-host fast event reaches _maybe_promote
        cidx = np.flatnonzero(counted)
        if cidx.size:
            cp = pg[cidx]
            acc_cp = ds.acc.arr[cp]
            # promotion needs a cache-resident page whose counter crosses
            # the threshold; cheap prescreen before the exact ranking
            resident = ds.cache_res[cp]
            maybe = resident & (acc_cp + cidx.size >= cfg.promote_threshold)
            if maybe.any():
                order = np.argsort(cp, kind="stable")
                sp = cp[order]
                newgrp = np.empty(sp.size, bool)
                newgrp[0] = True
                np.not_equal(sp[1:], sp[:-1], out=newgrp[1:])
                idx = np.arange(sp.size)
                grp_start = np.where(newgrp, idx, 0)
                np.maximum.accumulate(grp_start, out=grp_start)
                occ = np.empty(sp.size, np.int64)
                occ[order] = idx - grp_start
                cand = (acc_cp + occ + 1 >= cfg.promote_threshold) & resident
                if cand.any():
                    b = min(b, int(cidx[cand.argmax()]))
    return b


def _apply_prefix(m: BatchedMachine, cfg: SimConfig, th: Thread,
                  i: int, b: int, t: float, pg, ln, codes) -> float:
    """Retire events [i, i+b) of the thread's trace in bulk. All are
    state-stable under the snapshot; pg/ln/codes are chunk-local views."""
    st = m.stats
    ds = m.state
    fc = codes[:b]
    cnt = np.bincount(fc, minlength=8).tolist()
    n_hr, n_hw, n_log, n_cr, n_w4, n_w5, n_cw = cnt[:7]
    lats = m._lat_lut8[fc]
    # ONE cumsum replays all four sequential float chains of the reference
    # (`t += gap; t += lat` interleaved; `lat_sum += lat`; `lat_host += lat`
    # on host events; `lat_hit += lat` on the rest). Unused slots hold +0.0,
    # and IEEE x + 0.0 == x exactly, so each row reproduces the reference's
    # left-to-right addition order bit-for-bit.
    buf = np.zeros((4, 2 * b + 1))
    buf[:, 0] = (t, st.lat_sum, st.lat_host, st.lat_hit)
    buf[0, 1::2] = th.gap64[i:i + b]
    buf[:2, 2::2] = lats
    nh = n_hr + n_hw
    hostm = None
    if nh == b:
        buf[2, 2::2] = lats
    elif nh:
        hostm = fc < 2
        buf[2, 2::2] = lats * hostm
        buf[3, 2::2] = lats * ~hostm
    else:
        buf[3, 2::2] = lats
    t, st.lat_sum, st.lat_host, st.lat_hit = buf.cumsum(axis=1)[:, -1].tolist()
    # counters
    FUSED_STATS["vector_events"] += b
    st.n += b
    st.host_r += n_hr
    st.host_w += n_hw
    st.hit_log += n_log
    st.hit_cache += n_cr
    st.ssd_w += n_w4 + n_w5 + n_cw
    if cfg.dram_only:
        return t
    # lazy-but-exact state application
    fpg = pg[:b]
    if nh:
        move = ds.host.move_to_end
        hpg = fpg if nh == b else fpg[hostm]
        for p in _last_occurrence_order(hpg):
            move(p)
    if n_cr or n_cw:  # cache LRU (read hits + Base-CSSD write hits): the
        # stamp scatter IS the reference's per-event move-to-end sequence
        touch = fc == 3 if not n_cw else (fc == 3) | (fc == 6)
        m.cache.bulk_touch(fpg[touch])
    if n_cw:
        ds.cache_dirty[fpg[fc == 6]] = True  # all code-6 pages are resident
    if n_w4:
        wm = fc == 4
        m.log.bulk_append_new(fpg[wm], ln[:b][wm])
    if m._counting and nh != b:
        cpg = fpg if nh == 0 else fpg[~hostm]
        if cpg.size > 1024:  # bincount amortizes its page_space allocation
            ds.acc.arr += np.bincount(cpg, minlength=m.page_space)
        else:
            np.add.at(ds.acc.arr, cpg, 1)
    return t


def _insert_miss(ds, st, p, dirty, t, cclk, csets, cway, n_sets, ways, cres,
                 cdirty, cstamp, epoch_mv, journal, ftl_write):
    """Inlined DataCache.insert (page known non-resident) + dirty-victim
    write-back (Machine._handle_evict) over the shared state — the exact
    operation order of the methods it replaces, minus their dispatch.
    The write-back itself is ONE ``ftl_write`` dispatch: since the
    physical-routing refactor ``on_flash_write`` performs the whole
    program (destination resolution, bus/die timing at the frontier the
    FTL chose, mapping, GC) in both backends, so there is no timing code
    left to inline here. ``cclk`` is the caller's hoisted LRU clock;
    returns its new value.

    KEEP IN SYNC: the no-log span's flash-read-miss block repeats this
    body verbatim (dirty=False) — at that site, the hottest miss path in
    the ctx-bound cells, even this function's call overhead was measurable.
    Any change here must be mirrored there; the engine parity suites
    (test_engine.py / test_engine_cache.py) catch a missed mirror as a
    stat divergence on ctx/no-log configurations."""
    row = csets[p % n_sets]
    vw = 0
    vp = -1
    vs = None
    for w2 in range(ways):
        q = row[w2]
        if q < 0:  # free slot: no eviction needed
            vw = w2
            vp = -1
            break
        sq = cstamp[q]
        if vs is None or sq < vs:
            vs = sq
            vw = w2
            vp = q
    ec = ds.epoch_clock
    ev_dirty = False
    if vp >= 0:
        ev_dirty = cdirty[vp]
        cres[vp] = False
        cway[vp] = -1
        ec += 1
        epoch_mv[vp] = ec
        journal.append(vp)
    row[vw] = p
    cway[p] = vw
    cres[p] = True
    cdirty[p] = dirty
    cclk += 1
    cstamp[p] = cclk
    ec += 1
    epoch_mv[p] = ec
    journal.append(p)
    ds.epoch_clock = ec
    if ev_dirty:
        ftl_write(t, vp)  # full program: timing + mapping + GC
        st.flash_write_pages += 1
    return cclk


def _inline_span(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                 wslots, i: int, stop: int):
    """Exact per-event replay tuned for boundary-dense stretches.

    Trace columns are native Python lists (no per-event NumPy scalar
    boxing). Every serve() case is transcribed with identical operation
    order — including misses, write-log fills (direct _compact call),
    promotions (direct _maybe_promote call, which also keeps stochastic
    tpp/astriflash policies exact: the RNG stream is consumed in the same
    per-event order as the reference) and the coordinated-context-switch
    read miss (estimate -> read -> fill -> park). State is probed through
    the shared DeviceState memoryviews, and the entire miss machinery —
    channel/die timing, cache fill + victim eviction, dirty write-back,
    epoch bumps — is inlined over the same shared arrays (~3 us of call
    dispatch per miss otherwise, and misses are up to ~20% of all events
    in write-storm phases); the FTL (block mapping/GC or the legacy
    counter) is ONE prepacked `on_flash_write` dispatch per flash
    program, shared verbatim with the reference loop so the backends can
    never diverge between engines. Returns (i, t, blocked).
    """
    pages, lines, writes, gaps = m._columns(th)
    st = m.stats
    ds = m.state
    # latency provenance: staged inside the shared Channels.read (obs
    # forces the f_read dispatch), committed/discarded at the retire
    # sites below — same protocol as serve() (KEEP IN SYNC)
    obs = m.channels.obs
    # invariant locals (memoryviews over the shared state arrays, latency
    # constants, inlined-flash-timing constants) come prepacked — see
    # BatchedMachine._span_env. Python-int scalar get/set on a memoryview
    # is ~4x cheaper than NumPy scalar indexing; writes go through to the
    # same arrays the vector path gathers.
    (maybe_promote, compact, host, move_host, cres, cdirty, cstamp, csets,
     cway, n_sets, ways, epoch_mv, journal, promoting, skybyte_count, acc,
     promo_thr, lat_host, base, cache_idx, dram, lat_log, lat_cache,
     ctx_ns, ctx_thr, chan_bus, chan_die, n_ch, t_read, rd_busy,
     ftl_write, max_out, ctx_on, logbits, log_cap,
     l2p, loc_div, gc_from, gc_until, f_read) = m._span_env
    block_route = l2p is not None
    lat_hist = st.lat_hist
    lat_hist_w = st.lat_hist_w
    lb = _lat_bin
    log_on = logbits is not None
    if log_on:
        log_active = ds.log_active
        an = ds.log_active_n  # hoisted; written back around compactions
    # the host tier only ever gains pages through _maybe_promote: with
    # promotion off and the tier empty it stays empty for the whole span,
    # so the per-event membership probe can be skipped outright
    check_host = promoting or len(host) > 0
    # LRU clock hoisted to a local; synced back around every call that can
    # reach DataCache.lookup/insert through the policy layer
    # (_maybe_promote) and on exit
    cclk = ds.cache_clock
    # local accumulators: same sequential add order as _record, flushed on exit
    host_r = host_w = hit_log_n = hit_cache_n = miss_n = ssd_w_n = 0
    slow_n = bnd_n = k = 0
    lat_sum = st.lat_sum
    lat_host_acc = st.lat_host
    lat_hit_acc = st.lat_hit
    lat_miss_acc = st.lat_miss
    blocked = False
    if not log_on:
        # ================= specialized no-write-log loop =================
        # (Base-CSSD / -C / -P / -CP): the line column is never consumed,
        # one membership probe serves read AND write hits, and the read
        # miss — the quantum-ending event of the ctx-bound cells — runs
        # with its fill/evict/write-back machinery fully inlined.
        for p, w, g in zip(pages[i:stop], writes[i:stop], gaps[i:stop]):
            t += g
            k += 1
            if check_host and p in host:
                move_host(p)
                if w:
                    host_w += 1
                else:
                    host_r += 1
                lat_sum += lat_host
                lat_host_acc += lat_host
                t += lat_host
                continue
            if cres[p]:
                cclk += 1
                cstamp[p] = cclk  # LRU touch (serve's lookup)
                if w:
                    cdirty[p] = True  # mark_dirty
                    ssd_w_n += 1
                else:
                    hit_cache_n += 1
                if promoting:
                    if skybyte_count:
                        c = acc[p] + 1
                        if c >= promo_thr:  # resident by construction
                            ds.cache_clock = cclk
                            maybe_promote(p, t)
                            cclk = ds.cache_clock
                            bnd_n += 1
                        else:
                            acc[p] = c
                    else:  # tpp / astriflash: exact per-event RNG order
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                lat_sum += lat_cache
                lat_hit_acc += lat_cache
                t += lat_cache
                continue
            if w:
                # Base-CSSD write miss: posted store, background page
                # fetch in a write slot (transcribed from serve())
                stall = 0.0
                if len(wslots) >= max_out:
                    oldest = min(wslots)
                    wslots.remove(oldest)
                    if oldest > t:
                        stall = oldest - t
                # resolved location (physical placement under the block
                # FTL, logical hash stripe under legacy), then inlined
                # Channels.read at now = t + stall
                if block_route:
                    blk = l2p[p] // loc_div
                    ch = blk % n_ch
                    dd = (blk // n_ch) % DIES_PER_CHANNEL
                else:
                    ch = (p * 1103515245 + 12345) % n_ch
                    dd = (p // n_ch) % DIES_PER_CHANNEL
                now2 = t + stall
                if f_read is not None:  # fault path: shared Channels.read
                    done = f_read(ch, dd, now2, False)
                else:
                    die = chan_die[ch]
                    dv = die[dd]
                    # background fetch: no GC-pause attribution
                    # (gc_attr=False in the serve() path this transcribes)
                    sensed = (dv if dv > now2 else now2) + t_read
                    bv = chan_bus[ch]
                    done = (sensed if sensed > bv else bv) + TRANSFER_NS
                    die[dd] = sensed
                    chan_bus[ch] = done
                    ds.chan_busy_ns += rd_busy
                    ds.flash_reads += 1
                wslots.append(done)
                cclk = _insert_miss(ds, st, p, True, t, cclk, csets,
                                    cway, n_sets, ways, cres, cdirty,
                                    cstamp, epoch_mv, journal, ftl_write)
                bnd_n += 1
                if promoting:
                    if skybyte_count:
                        c = acc[p] + 1
                        if c >= promo_thr:  # just inserted -> resident
                            ds.cache_clock = cclk
                            maybe_promote(p, t)
                            cclk = ds.cache_clock
                            bnd_n += 1
                        else:
                            acc[p] = c
                    else:
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                ssd_w_n += 1
                lat = stall + base + cache_idx + dram
                if stall > 0.0:  # variable latency: tail-histogram it
                    st.ssd_w_var += 1
                    lat_hist_w[lb(lat)] += 1
                    if obs is not None:
                        obs.commit_write_stall(lat, stall, t)
                lat_sum += lat
                lat_hit_acc += lat
                t += lat
                continue
            # ---- flash read miss (transcribed from serve(); when the
            # coordinated context switch is on, Algorithm 1's estimator
            # decides between parking the thread and serving inline).
            # The location is the page's PHYSICAL placement under the
            # block FTL (live l2p — mapping changes only ever happen on
            # boundary paths like this one), the logical stripe under
            # legacy. ----
            if block_route:
                blk = l2p[p] // loc_div
                ch = blk % n_ch
                dd = (blk // n_ch) % DIES_PER_CHANNEL
            else:
                ch = (p * 1103515245 + 12345) % n_ch
                dd = (p // n_ch) % DIES_PER_CHANNEL
            die = chan_die[ch]
            dv = die[dd]
            bv = chan_bus[ch]
            if ctx_on:  # inlined Channels.estimate (pre-issue state)
                dw = dv - t
                bw = bv - t
                wait = dw if dw > bw else bw
                est = (wait if wait > 0.0 else 0.0) + t_read
            if f_read is not None:  # fault path: shared Channels.read
                done = f_read(ch, dd, t)
            else:
                if dv > t:  # GC-pause attribution (Channels.read mirror)
                    gu = gc_until[ch][dd]
                    if gu > t:
                        gf = gc_from[ch][dd]
                        lo = t if t > gf else gf
                        hi = dv if dv < gu else gu
                        pause = hi - lo
                        if pause > 0.0:
                            ds.gc_stall_events += 1
                            ds.gc_pause_ns_total += pause
                            if pause > ds.gc_pause_max_ns:
                                ds.gc_pause_max_ns = pause
                # inlined Channels.read
                sensed = (dv if dv > t else t) + t_read
                done = (sensed if sensed > bv else bv) + TRANSFER_NS
                die[dd] = sensed
                chan_bus[ch] = done
                ds.chan_busy_ns += rd_busy
                ds.flash_reads += 1
            # inlined DataCache.insert(p, False) + victim write-back:
            # verbatim body of _insert_miss (KEEP IN SYNC with it — this
            # is the one site hot enough to shed the call overhead)
            row = csets[p % n_sets]
            vw = 0
            vp = -1
            vs = None
            for w2 in range(ways):
                q = row[w2]
                if q < 0:  # free slot: no eviction needed
                    vw = w2
                    vp = -1
                    break
                sq = cstamp[q]
                if vs is None or sq < vs:
                    vs = sq
                    vw = w2
                    vp = q
            ec = ds.epoch_clock
            ev_dirty = False
            if vp >= 0:
                ev_dirty = cdirty[vp]
                cres[vp] = False
                cway[vp] = -1
                ec += 1
                epoch_mv[vp] = ec
                journal.append(vp)
            row[vw] = p
            cway[p] = vw
            cres[p] = True
            cdirty[p] = False
            cclk += 1
            cstamp[p] = cclk
            ec += 1
            epoch_mv[p] = ec
            journal.append(p)
            ds.epoch_clock = ec
            if ev_dirty:
                ftl_write(t, vp)  # full program: timing + mapping + GC
                st.flash_write_pages += 1
            if ctx_on and est > ctx_thr:
                st.ctx_switches += 1
                if obs is not None:
                    obs.on_park()  # staged read parks: no host retire
                if promoting:
                    if skybyte_count:
                        c = acc[p] + 1
                        if c >= promo_thr:  # just inserted -> resident
                            ds.cache_clock = cclk
                            maybe_promote(p, t)
                            cclk = ds.cache_clock
                        else:
                            acc[p] = c
                    else:
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                slow_n += 1
                th.ready = done
                th.replay = True
                t += ctx_ns
                k -= 1  # squashed access: replayed after wakeup
                blocked = True
                break
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # just inserted -> resident
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
            bnd_n += 1
            lat = (done - t) + base + cache_idx + dram
            miss_n += 1
            lat_hist[lb(lat)] += 1
            if obs is not None:
                obs.commit_read_miss(lat)
            lat_sum += lat
            lat_miss_acc += lat
            t += lat
        ds.cache_clock = cclk
        if k:
            m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
        FUSED_STATS["span_events"] += k
        st.n += k
        st.host_r += host_r
        st.host_w += host_w
        st.hit_cache += hit_cache_n
        st.miss_flash += miss_n
        st.ssd_w += ssd_w_n
        st.lat_sum = lat_sum
        st.lat_host = lat_host_acc
        st.lat_hit = lat_hit_acc
        st.lat_miss = lat_miss_acc
        return i + k, t, blocked
    # ==================== write-log loop (-W variants) ====================
    for p, l, w, g in zip(pages[i:stop], lines[i:stop], writes[i:stop],
                          gaps[i:stop]):
        t += g
        k += 1
        if check_host and p in host:
            move_host(p)
            if w:
                host_w += 1
            else:
                host_r += 1
            lat_sum += lat_host
            lat_host_acc += lat_host
            t += lat_host
            continue
        if w:
            # cacheline write log append (serve(): append -> compact
            # if full -> promote)
            e = log_active.get(p)
            if e is None or l not in e:
                if e is None:
                    e = log_active[p] = {}
                e[l] = True
                # no epoch bump: cached codes absorb new lines through
                # the per-chunk log overlay, not page repair
                logbits[p] = logbits[p] | (1 << l)
                an += 1
                if an >= log_cap:  # filled: drain the old buffer
                    ds.log_active_n = an
                    compact(t)
                    log_active = ds.log_active
                    an = ds.log_active_n
                    bnd_n += 1
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr and cres[p]:
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:  # tpp / astriflash: exact per-event RNG order
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
            ssd_w_n += 1
            lat_sum += lat_log
            lat_hit_acc += lat_log
            t += lat_log
            continue
        # ---- read ----
        e = log_active.get(p)
        if e is not None and l in e:
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr and cres[p]:
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
            hit_log_n += 1
            lat_sum += lat_log
            lat_hit_acc += lat_log
            t += lat_log
            continue
        if cres[p]:
            cclk += 1
            cstamp[p] = cclk  # LRU touch
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # resident -> promotion fires
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                        bnd_n += 1
                    else:
                        acc[p] = c
                else:
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
            hit_cache_n += 1
            lat_sum += lat_cache
            lat_hit_acc += lat_cache
            t += lat_cache
            continue
        # ---- flash read miss (transcribed from serve(); when the
        # coordinated context switch is on, Algorithm 1's estimator decides
        # between parking the thread and serving the miss inline). The
        # location is the page's physical placement (live l2p) under the
        # block FTL, the logical hash stripe under legacy. ----
        if block_route:
            blk = l2p[p] // loc_div
            ch = blk % n_ch
            dd = (blk // n_ch) % DIES_PER_CHANNEL
        else:
            ch = (p * 1103515245 + 12345) % n_ch
            dd = (p // n_ch) % DIES_PER_CHANNEL
        die = chan_die[ch]
        dv = die[dd]
        bv = chan_bus[ch]
        if ctx_on:  # inlined Channels.estimate (reads pre-issue state)
            dw = dv - t
            bw = bv - t
            wait = dw if dw > bw else bw
            est = (wait if wait > 0.0 else 0.0) + t_read
        if f_read is not None:  # fault path: shared Channels.read
            done = f_read(ch, dd, t)
        else:
            if dv > t:  # GC-pause attribution (Channels.read mirror)
                gu = gc_until[ch][dd]
                if gu > t:
                    gf = gc_from[ch][dd]
                    lo = t if t > gf else gf
                    hi = dv if dv < gu else gu
                    pause = hi - lo
                    if pause > 0.0:
                        ds.gc_stall_events += 1
                        ds.gc_pause_ns_total += pause
                        if pause > ds.gc_pause_max_ns:
                            ds.gc_pause_max_ns = pause
            # inlined Channels.read
            sensed = (dv if dv > t else t) + t_read
            done = (sensed if sensed > bv else bv) + TRANSFER_NS
            die[dd] = sensed
            chan_bus[ch] = done
            ds.chan_busy_ns += rd_busy
            ds.flash_reads += 1
        cclk = _insert_miss(ds, st, p, False, t, cclk, csets, cway, n_sets,
                            ways, cres, cdirty, cstamp, epoch_mv, journal,
                            ftl_write)
        if ctx_on and est > ctx_thr:
            st.ctx_switches += 1
            if obs is not None:
                obs.on_park()  # staged read parks: no host retire
            if promoting:
                if skybyte_count:
                    c = acc[p] + 1
                    if c >= promo_thr:  # just inserted -> resident
                        ds.cache_clock = cclk
                        maybe_promote(p, t)
                        cclk = ds.cache_clock
                    else:
                        acc[p] = c
                else:
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
            slow_n += 1
            th.ready = done
            th.replay = True
            t += ctx_ns
            k -= 1  # squashed access: replayed later, not retired now
            blocked = True
            break
        if promoting:
            if skybyte_count:
                c = acc[p] + 1
                if c >= promo_thr:  # just inserted -> resident
                    ds.cache_clock = cclk
                    maybe_promote(p, t)
                    cclk = ds.cache_clock
                    bnd_n += 1
                else:
                    acc[p] = c
            else:
                ds.cache_clock = cclk
                maybe_promote(p, t)
                cclk = ds.cache_clock
        bnd_n += 1
        lat = (done - t) + base + cache_idx + dram
        miss_n += 1
        lat_hist[lb(lat)] += 1
        if obs is not None:
            obs.commit_read_miss(lat)
        lat_sum += lat
        lat_miss_acc += lat
        t += lat
    ds.cache_clock = cclk
    if log_on:
        ds.log_active_n = an
    if k:
        m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
    FUSED_STATS["span_events"] += k
    st.n += k
    st.host_r += host_r
    st.host_w += host_w
    st.hit_log += hit_log_n
    st.hit_cache += hit_cache_n
    st.miss_flash += miss_n
    st.ssd_w += ssd_w_n
    st.lat_sum = lat_sum
    st.lat_host = lat_host_acc
    st.lat_hit = lat_hit_acc
    st.lat_miss = lat_miss_acc
    # k counts retired events; on a block the squashed access sits at i + k
    # and is replayed when the thread wakes (same as the reference loop)
    return i + k, t, blocked


def _classify_few(m: BatchedMachine, th: Thread, cc: _ClsCache,
                  pos) -> None:
    """Scalar-path re-classification of a few ascending trace positions
    (same semantics as _classify_positions, via the state memoryviews)."""
    pages, lines, writes, _ = m._columns(th)
    ds = m.state
    hostv = ds.host._mv
    cachev = ds.cache_res_mv
    bits = memoryview(ds.log_bits) if m.log is not None else None
    codes_mv = memoryview(cc.codes)
    seen = set()
    for x in pos.tolist():
        p = pages[x]
        w = writes[x]
        if hostv[p]:
            codes_mv[x] = 1 if w else 0
            continue
        if bits is None:
            codes_mv[x] = (6 if w else 3) if cachev[p] else 7
            continue
        l = lines[x]
        pr = p * 64 + l
        present = (bits[p] >> l) & 1 or pr in seen
        if w:
            if present:
                codes_mv[x] = 5
            else:
                codes_mv[x] = 4
                seen.add(pr)
        elif present:
            codes_mv[x] = 2
        else:
            codes_mv[x] = 3 if cachev[p] else 7


def _fold_boundary(m: BatchedMachine, cfg: SimConfig, th: Thread,
                   cc: _ClsCache, i: int) -> None:
    """Fold the pages mutated by the boundary event just executed (state
    journal) back into the live cached range, then advance the stamp.

    Advancing the stamp here is sound because quanta are serial: between
    the quantum-entry validation and now, the ONLY state mutations are this
    thread's own boundary events, and their pages are exactly the journal.
    Folding in place keeps the common ctx-switch cycle — miss on page p,
    insert p, evict q, park — from failing the next validation: p is
    usually re-accessed immediately (spatial runs)."""
    ds = m.state
    jl = ds.journal
    if jl:
        if len(jl) <= 24:
            CACHE_STATS["folds"] += 1
            pgr = th.page[i:cc.hi]
            mask = pgr == jl[0]
            for p in jl[1:]:
                mask |= pgr == p
            pos = np.flatnonzero(mask)
            if pos.size:
                pos += i
                if pos.size <= 24:
                    # scalar re-classification: a handful of positions is
                    # not worth ~20 NumPy dispatches
                    _classify_few(m, th, cc, pos)
                else:
                    cc.codes[pos] = _classify_positions(
                        m, cfg, th.page[pos], th.line[pos], th.write[pos])
        else:  # flood (compaction drained the log): reclassify wholesale
            _refresh_cache(m, cfg, th, cc, i, m.chunk)
        jl.clear()
    cc.stamp = ds.epoch_clock


def batched_quantum(m: BatchedMachine, cfg: SimConfig, th: Thread, t: float,
                    wslots) -> float:
    """Run one scheduling quantum with the batched engine. Semantically
    identical to simulator._reference_quantum."""
    i, n = th.i, th.n
    if th.replay:
        # inlined _replay_prologue (§III-A 4): the replayed access is
        # charged as an SSD DRAM hit; identical accounting order
        th.replay = False
        st = m.stats
        lat = m._lat_cache
        t += lat
        st.n += 1
        st.lat_sum += lat
        st.hit_cache += 1
        st.lat_hit += lat
        st.replays += 1
        i += 1
    ds = m.state
    ds.journal.clear()  # only this quantum's boundary bumps matter
    blocked = False
    cc = None
    min_run = m._min_run
    use_cache = m._use_cache
    while i < n and not blocked:
        if m.runlen < min_run or m._inline_only:
            # boundary-dense stretch: per-event inline replay beats even a
            # pre-classified vector pass (repairing the cache at every
            # boundary would dominate); the span reports observed run
            # lengths back into the EWMA so the engine re-vectorizes when
            # runs lengthen again. With coordinated context switches on,
            # quanta end after ~1/miss-rate events — size the span window
            # to the observed run length so the four trace-column slices
            # copy what the quantum will consume, not _SPAN events of it
            # (the while loop re-enters if the thread outlives the window).
            cc = None
            lim = _SPAN
            if cfg.enable_ctx_switch:
                r = int(m.runlen)
                lim = 4 * r + 64 if r < 240 else _SPAN
            i, t, blocked = _inline_span(m, cfg, th, t, wslots, i,
                                         min(i + lim, n))
            continue
        j = min(i + m.chunk, n)
        if use_cache:
            if cc is None:
                cc = m._caches.get(th.tid)
                if cc is None:
                    cc = _ClsCache(n)
                    m._caches[th.tid] = cc
                if i < cc.lo or i >= cc.hi:
                    CACHE_STATS["builds"] += 1
                    _refresh_cache(m, cfg, th, cc, i, j - i)
                else:
                    # re-entry validation: one epoch gather over the
                    # remaining range decides whether any of its pages
                    # changed membership since the stamp — usually not,
                    # so the whole quantum consumes cached codes as-is
                    CACHE_STATS["checks"] += 1
                    if int(ds.page_epoch[th.page[i:cc.hi]].max()) > cc.stamp:
                        CACHE_STATS["repairs"] += 1
                        _refresh_cache(m, cfg, th, cc, i, j - i)
                    else:
                        CACHE_STATS["clean"] += 1
                cc.stamp = ds.epoch_clock
                ds.journal.clear()
            if j > cc.hi:  # chunk overruns the (validated) range
                CACHE_STATS["builds"] += 1
                _refresh_cache(m, cfg, th, cc, i, j - i)
            codes = cc.codes[i:j]
            pg = th.page[i:j]
            ln = th.line[i:j]
        else:
            pg = th.page[i:j]
            ln = th.line[i:j]
            codes = _classify_positions(m, cfg, pg, ln, th.write[i:j])
        b = _next_boundary(m, cfg, pg, codes)
        if b > 0:
            if use_cache and m.log is not None:
                _log_overlay(m, th, i, b, pg, ln, codes)
            t = _apply_prefix(m, cfg, th, i, b, t, pg, ln, codes)
            i += b
        if b < pg.shape[0]:  # boundary inside the chunk
            m.runlen += 0.25 * (b - m.runlen)
            # ---- transcribed slow path for the state-changing event.
            # Every case replicates Machine.serve()'s operation order to
            # the letter; serve() itself is never called. Classification
            # proves host/cache membership (epoch-validated); only the
            # append-monotone write log needs a live probe — a line may
            # have arrived since classification. ----
            t = t + th.gap64[i]
            kb = int(codes[b])
            pgb = int(pg[b])
            wrb = bool(th.write[i])
            log_on = m.log is not None
            if kb == 7 and not wrb:
                # flash read miss per classification (host/cache
                # non-resident)
                e = ds.log_active.get(pgb) if log_on else None
                if e is not None and int(ln[b]) in e:
                    # line arrived since classification: an exact log hit
                    m._maybe_promote(pgb, t)
                    lat = m._lat_log
                    t += lat
                    _record(m.stats, "hit_log", lat)
                    i += 1
                else:
                    # service location = live physical placement (block
                    # FTL) or the logical hash stripe (legacy)
                    chb, ddb = m.loc_of(pgb)
                    ctx_on = cfg.enable_ctx_switch
                    if ctx_on:
                        est = m.channels.estimate(chb, ddb, t)
                    done = m.channels.read(chb, ddb, t)
                    ev = m.cache.insert(pgb, False)
                    m._handle_evict(ev, t)
                    obs = m.channels.obs
                    if ctx_on and est > cfg.ctx_threshold_ns:
                        # Algorithm 1 fires: park the thread (§III-A)
                        m.stats.ctx_switches += 1
                        if obs is not None:
                            obs.on_park()  # staged read parks: no retire
                        m._maybe_promote(pgb, t)
                        th.ready = done
                        th.replay = True
                        t += cfg.ctx_switch_ns
                        blocked = True
                    else:
                        m._maybe_promote(pgb, t)
                        # same left-to-right addition order as serve()
                        lat = (done - t) + cfg.cxl_protocol_ns \
                            + cfg.cache_index_ns + cfg.ssd_dram_ns
                        if obs is not None:
                            obs.commit_read_miss(lat)
                        t += lat
                        _record(m.stats, "miss_flash", lat)
                        i += 1
            elif kb == 7:
                # Base-CSSD write miss (log off: all logged writes are
                # codes 4/5): posted store, background page fetch in a
                # write slot
                stall = 0.0
                if len(wslots) >= cfg.max_outstanding:
                    oldest = min(wslots)
                    wslots.remove(oldest)
                    if oldest > t:
                        stall = oldest - t
                wslots.append(m.channels.read(*m.loc_of(pgb), t + stall,
                                              gc_attr=False))
                ev = m.cache.insert(pgb, True)
                m._handle_evict(ev, t)
                m._maybe_promote(pgb, t)
                lat = stall + cfg.cxl_protocol_ns + cfg.cache_index_ns \
                    + cfg.ssd_dram_ns
                if stall > 0.0:  # variable latency: tail-histogram it
                    m.stats.ssd_w_var += 1
                    m.stats.lat_hist_w[_lat_bin(lat)] += 1
                    obs = m.channels.obs
                    if obs is not None:
                        obs.commit_write_stall(lat, stall, t)
                t += lat
                _record(m.stats, "ssd_w", lat)
                i += 1
            elif wrb:
                if log_on:
                    # logged write at a predicted boundary: the append may
                    # fill the active buffer (compaction drain), and/or the
                    # access may cross the promotion threshold
                    full = m.log.append(pgb, int(ln[b]))
                    if full:
                        m._compact(t)
                    m._maybe_promote(pgb, t)
                    lat = m._lat_log
                    _record(m.stats, "ssd_w", lat)
                else:
                    # cache write hit with a predicted promotion
                    m.cache.lookup(pgb)  # LRU touch (serve's order)
                    m.cache.mark_dirty(pgb)
                    m._maybe_promote(pgb, t)
                    lat = m._lat_cache
                    _record(m.stats, "ssd_w", lat)
                t += lat
                i += 1
            else:
                # read hit (log or cache) with a predicted promotion; the
                # log probe is live because appends don't bump epochs
                e = ds.log_active.get(pgb) if log_on else None
                if e is not None and int(ln[b]) in e:
                    m._maybe_promote(pgb, t)
                    lat = m._lat_log
                    _record(m.stats, "hit_log", lat)
                else:
                    m.cache.lookup(pgb)  # LRU touch
                    m._maybe_promote(pgb, t)
                    lat = m._lat_cache
                    _record(m.stats, "hit_cache", lat)
                t += lat
                i += 1
            if cc is not None:
                _fold_boundary(m, cfg, th, cc, i)
            m.chunk = max(_CHUNK_FLOOR, min(_CHUNK_MAX, 2 * b + 32))
        else:
            m.chunk = min(_CHUNK_MAX, m.chunk * 2)
    th.i = i
    return t


def _stage_windows(m: BatchedMachine, cfg: SimConfig, th: Thread,
                   i: int) -> _ClsCache:
    """Cross-thread window staging for the fused kernel.

    Classifies the upcoming trace window of the requesting thread AND of
    every other pending thread whose staged range is exhausted in ONE flat
    vector pass over concatenated event arrays, then scatters the codes
    back into the per-thread classification caches (stamped at the current
    epoch, so the vector path can validate and consume them unchanged).
    This amortizes the classifier's fixed NumPy dispatch cost across the
    whole runnable set — at ctx-bound quantum sizes (~50 events) per-thread
    staging would pay that cost ~24x per scheduling round. Store-to-load
    forwarding cannot leak between threads: concatenated segments get
    composite (page, line) keys via _classify_positions' ``pair_base``.

    The staged code-7 positions (``sevens``) feed the kernel's window
    sizing only; every event is still live-probed against the shared
    state, so cross-thread staleness (another thread evicting or
    inserting a page between staging and consumption) costs at most a
    mis-sized window, never a wrong result."""
    caches = m._caches
    want = max(min(4 * m.chunk, m._window), 512)
    reqs = []

    def _need(th2, lo):
        cc2 = caches.get(th2.tid)
        if cc2 is None:
            cc2 = _ClsCache(th2.n)
            caches[th2.tid] = cc2
        reqs.append((th2, cc2, lo, min(th2.n, lo + want)))
        return cc2

    cc = _need(th, i)
    threads = m._threads
    if threads is not None:
        for th2 in threads:
            if th2 is th or th2.done or th2.i >= th2.n:
                continue
            cc2 = caches.get(th2.tid)
            if cc2 is not None and cc2.lo <= th2.i < cc2.hi:
                continue  # still holds a live staged range
            _need(th2, th2.i)
    if len(reqs) == 1:
        _refresh_cache(m, cfg, th, cc, i, want)
        return cc
    pg = np.concatenate([t2.page[lo:hi] for t2, _, lo, hi in reqs])
    ln = np.concatenate([t2.line[lo:hi] for t2, _, lo, hi in reqs])
    wr = np.concatenate([t2.write[lo:hi] for t2, _, lo, hi in reqs])
    if m.log is None:
        codes = _classify_positions(m, cfg, pg, ln, wr)
    else:
        sizes = [hi - lo for _, _, lo, hi in reqs]
        pb = np.repeat(
            np.arange(len(reqs), dtype=np.int64) * m.page_space, sizes)
        codes = _classify_positions(m, cfg, pg, ln, wr, pair_base=pb)
    ec = m.state.epoch_clock
    predict = m._predict
    FUSED_STATS["stage_rounds"] += 1
    off = 0
    for th2, cc2, lo, hi in reqs:
        w2 = hi - lo
        seg = codes[off:off + w2]
        cc2.codes[lo:hi] = seg
        off += w2
        cc2.lo = lo
        cc2.hi = hi
        cc2.stamp = ec
        if predict:
            cc2.sevens = (np.flatnonzero(seg == 7) + lo).tolist()
            cc2.sp = 0
        FUSED_STATS["staged_threads"] += 1
        CACHE_STATS["classified"] += w2
    return cc


def run_fused(m: BatchedMachine, cfg: SimConfig, threads) -> list:
    """Cross-thread fused scheduling loop — the batched engine's driver.

    KEEP IN SYNC with simulator._run_scheduler: the scheduler selection
    here is a verbatim copy (same wake condition, same (key, tid)
    tie-break, same RANDOM rng stream), with the boundary-dense span
    kernel fused INTO the scheduling loop. That fusion is what breaks the
    per-quantum floor of the old per-thread span: the ~38 span environment
    bindings and the four sequential float accumulator chains (core time
    excepted — it is per-quantum by construction) live in loop locals for
    the WHOLE run instead of being re-derived and re-flushed per quantum
    (~4700 times in the ctx-bound cells), windows are sized by the staged
    boundary predictions from _stage_windows instead of a blind multiple
    of the run-length EWMA (so the four trace-column slices copy what the
    quantum will actually consume), and every event is still live-probed
    through the shared memoryviews, which keeps the kernel bit-exact under
    any cross-thread churn: a stale prediction mis-sizes a window, it can
    never mis-classify an event.

    Vector-regime stretches (run lengths above cls_cache_min_run) flush
    the localized stats and delegate the rest of the quantum to
    batched_quantum, whose chunked classify/validate/apply machinery is
    unchanged. Inline-only configs (tpp/astriflash: per-event RNG order)
    and dram-only runs (pure vector path) use the plain scheduler around
    batched_quantum directly. Returns the per-core clock list."""
    if (m._inline_only or cfg.dram_only or m.channels.fault is not None
            or m.channels.qos is not None
            or m.channels.obs is not None):
        # Fault injection, die-level QoS and latency provenance (obs) are
        # conflict classes: the mega-loop's three inlined flash-read
        # sites would bypass the FaultModel (retry ladders, outages,
        # scheduled power loss / die failure), the QosModel (GC
        # suspend/resume, read-priority arbitration) and the ObsModel's
        # per-request staging, and a power-loss restart mutates
        # cache/timeline state out from under the fused loop's hoisted
        # locals. The scheduler + batched_quantum route every flash read
        # through the shared Channels.read (the span's miss sites
        # dispatch to it via _span_env's f_read), so parity with the
        # reference engine holds with faults, QoS or obs on. Note
        # superblock alone is NOT a conflict: it changes the loc_div
        # placement divisor, not arbitration.
        return _run_scheduler(m, cfg, threads, batched_quantum)
    m._threads = threads
    st = m.stats
    ds = m.state
    # ---- scheduler state (verbatim from simulator._run_scheduler) ----
    n_cores = cfg.n_cores
    cores = [0.0] * n_cores
    wslots_per_core = [[] for _ in range(n_cores)]
    sched_counter = 0
    nt = len(threads)
    n_alive = nt
    vrun = [0.0] * nt
    last_sched = [0] * nt
    use_cfs = cfg.sched_policy == "CFS"
    use_random = cfg.sched_policy == "RANDOM"
    heappush, heappop = heapq.heappush, heapq.heappop
    insort = bisect.insort
    wake_q = []
    if use_random:
        run_l = list(range(nt))  # all runnable at t=0, thread-index order
        rng_choice = m.rng.choice
    else:
        keys = vrun if use_cfs else last_sched
        run_q = [(0, ti) for ti in range(nt)]  # all runnable, key 0
    # ---- span environment, hoisted ONCE for the whole run ----
    (maybe_promote, compact, host, move_host, cres, cdirty, cstamp, csets,
     cway, n_sets, ways, epoch_mv, journal, promoting, skybyte_count, acc,
     promo_thr, lat_host, base, cache_idx, dram, lat_log, lat_cache,
     ctx_ns, ctx_thr, chan_bus, chan_die, n_ch, t_read, rd_busy,
     ftl_write, max_out, ctx_on, logbits, log_cap,
     l2p, loc_div, gc_from, gc_until, f_read) = m._span_env
    block_route = l2p is not None
    log_on = logbits is not None
    lat_hist = st.lat_hist
    lat_hist_w = st.lat_hist_w
    lb = _lat_bin
    journal_clear = journal.clear
    # host tier only ever gains pages through _maybe_promote: constant gate
    check_host = promoting or len(host) > 0
    min_run = m._min_run
    predict = m._predict
    caches = m._caches
    columns = m._columns
    replay_lat = m._lat_cache
    # Host-LRU moves are DEFERRED: the hit path appends the touched page
    # to a buffer and the authoritative OrderedDict is only reordered at
    # the points that actually read LRU order (_maybe_promote's demotion
    # pop, the vector path's own move pass) — applied per unique page in
    # ascending last-touch order, which reproduces the per-touch
    # move_to_end order exactly (a page's final position is set by its
    # LAST move). Membership (`p in host`, host.arr) is not affected by
    # pending moves, so probes stay exact between flushes.
    hbuf: list = []
    hbuf_app = hbuf.append

    def hflush():
        if hbuf:
            for q in reversed(dict.fromkeys(reversed(hbuf))):
                move_host(q)
            del hbuf[:]
    if log_on:
        log_active = ds.log_active
        log_get = log_active.get
    # ---- stats accumulators, localized across quanta (flushed around
    # vector-path delegations, which read/write Stats directly) ----
    n_acc = st.n
    host_r_n = st.host_r
    host_w_n = st.host_w
    hit_log_n = st.hit_log
    hit_cache_n = st.hit_cache
    miss_n = st.miss_flash
    ssd_w_n = st.ssd_w
    ssd_w_var_n = st.ssd_w_var
    ctx_sw_n = st.ctx_switches
    replays_n = st.replays
    lat_sum = st.lat_sum
    lat_host_acc = st.lat_host
    lat_hit_acc = st.lat_hit
    lat_miss_acc = st.lat_miss
    fused_n = 0

    while n_alive:
        # core with the earliest time (first minimal index)
        t_now = min(cores)
        c = cores.index(t_now)
        if use_random:
            while wake_q and wake_q[0][0] <= t_now:
                insort(run_l, heappop(wake_q)[1])
            if not run_l:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = rng_choice(run_l)
            run_l.remove(ti)
        else:
            while wake_q and wake_q[0][0] <= t_now:
                ti = heappop(wake_q)[1]
                heappush(run_q, (keys[ti], ti))
            if not run_q:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = heappop(run_q)[1]
        sched_counter += 1
        last_sched[ti] = sched_counter
        th = threads[ti]
        rdy = th.ready
        t = t_now if t_now >= rdy else rdy
        t0 = t
        wslots = wslots_per_core[c]
        # ---------------- one fused scheduling quantum ----------------
        i = th.i
        n = th.n
        if th.replay:
            # inlined _replay_prologue (§III-A 4): the replayed access is
            # charged as an SSD DRAM hit; identical accounting order
            th.replay = False
            t += replay_lat
            n_acc += 1
            lat_sum += replay_lat
            hit_cache_n += 1
            lat_hit_acc += replay_lat
            replays_n += 1
            i += 1
        journal_clear()  # only this quantum's boundary bumps matter
        blocked = False
        while i < n and not blocked:
            if m.runlen >= min_run:
                # vector regime: flush localized stats, hand the rest of
                # the quantum to the chunked vector machinery, reload
                th.i = i
                st.n = n_acc
                st.host_r = host_r_n
                st.host_w = host_w_n
                st.hit_log = hit_log_n
                st.hit_cache = hit_cache_n
                st.miss_flash = miss_n
                st.ssd_w = ssd_w_n
                st.ssd_w_var = ssd_w_var_n
                st.ctx_switches = ctx_sw_n
                st.replays = replays_n
                st.lat_sum = lat_sum
                st.lat_host = lat_host_acc
                st.lat_hit = lat_hit_acc
                st.lat_miss = lat_miss_acc
                hflush()  # vector path reads and reorders the host LRU
                t = batched_quantum(m, cfg, th, t, wslots)
                n_acc = st.n
                host_r_n = st.host_r
                host_w_n = st.host_w
                hit_log_n = st.hit_log
                hit_cache_n = st.hit_cache
                miss_n = st.miss_flash
                ssd_w_n = st.ssd_w
                ssd_w_var_n = st.ssd_w_var
                ctx_sw_n = st.ctx_switches
                replays_n = st.replays
                lat_sum = st.lat_sum
                lat_host_acc = st.lat_host
                lat_hit_acc = st.lat_hit
                lat_miss_acc = st.lat_miss
                i = th.i
                if log_on:  # compaction may have swapped the active dict
                    log_active = ds.log_active
                    log_get = log_active.get
                break
            # ---- fused kernel: one staged window ----
            rint = int(m.runlen)
            if predict:
                cc = caches.get(th.tid)
                if cc is None or i >= cc.hi or i < cc.lo:
                    cc = _stage_windows(m, cfg, th, i)
                sv = cc.sevens
                sp = cc.sp
                nsv = len(sv)
                while sp < nsv and sv[sp] < i:
                    sp += 1
                cc.sp = sp
                # window ends just past the next PREDICTED boundary; the
                # run-length floor absorbs clustered false predictions
                # (e.g. re-touches of a page inserted mid-window)
                stop = sv[sp] + 1 if sp < nsv else cc.hi
                floor_ = i + rint + 32
                if stop < floor_:
                    stop = floor_
            elif ctx_on:
                stop = i + rint + (rint >> 1) + 48
            else:
                stop = i + _SPAN
            if stop > n:
                stop = n
            pages, lines, writes, gaps = columns(th)
            cclk = ds.cache_clock
            k = 0
            slow_n = 0
            bnd_n = 0
            hp_last = -1  # host-LRU dedupe: consecutive touches are no-ops
            if not log_on:
                # ============== specialized no-write-log loop ==============
                # KEEP IN SYNC with _inline_span's no-log loop (the scalar
                # fallback): identical operation order per event, plus the
                # fused-only micro-opts (host-move dedupe, persistent
                # accumulators) that cannot change observable order. In this
                # driver promotion is always the counting "skybyte" policy
                # (stochastic policies took the plain-scheduler exit above).
                for p, w, g in zip(pages[i:stop], writes[i:stop],
                                   gaps[i:stop]):
                    t += g
                    k += 1
                    if check_host and p in host:
                        if p != hp_last:
                            hbuf_app(p)  # deferred LRU move, see hflush
                            hp_last = p
                        if w:
                            host_w_n += 1
                        else:
                            host_r_n += 1
                        lat_sum += lat_host
                        lat_host_acc += lat_host
                        t += lat_host
                        continue
                    if cres[p]:
                        cclk += 1
                        cstamp[p] = cclk  # LRU touch (serve's lookup)
                        if w:
                            cdirty[p] = True  # mark_dirty
                            ssd_w_n += 1
                        else:
                            hit_cache_n += 1
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # resident by construction
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        lat_sum += lat_cache
                        lat_hit_acc += lat_cache
                        t += lat_cache
                        continue
                    if w:
                        # Base-CSSD write miss: posted store, background
                        # page fetch in a write slot
                        stall = 0.0
                        if len(wslots) >= max_out:
                            oldest = min(wslots)
                            wslots.remove(oldest)
                            if oldest > t:
                                stall = oldest - t
                        if block_route:
                            blk = l2p[p] // loc_div
                            ch = blk % n_ch
                            dd = (blk // n_ch) % DIES_PER_CHANNEL
                        else:
                            ch = (p * 1103515245 + 12345) % n_ch
                            dd = (p // n_ch) % DIES_PER_CHANNEL
                        die = chan_die[ch]
                        now2 = t + stall
                        dv = die[dd]
                        # background fetch: no GC-pause attribution
                        sensed = (dv if dv > now2 else now2) + t_read
                        bv = chan_bus[ch]
                        done = (sensed if sensed > bv else bv) + TRANSFER_NS
                        die[dd] = sensed
                        chan_bus[ch] = done
                        ds.chan_busy_ns += rd_busy
                        ds.flash_reads += 1
                        wslots.append(done)
                        # inlined DataCache.insert(p, True) + write-back
                        # (KEEP IN SYNC with _insert_miss)
                        row = csets[p % n_sets]
                        vw = 0
                        vp = -1
                        vs = None
                        for w2 in range(ways):
                            q = row[w2]
                            if q < 0:
                                vw = w2
                                vp = -1
                                break
                            sq = cstamp[q]
                            if vs is None or sq < vs:
                                vs = sq
                                vw = w2
                                vp = q
                        ec = ds.epoch_clock
                        ev_dirty = False
                        if vp >= 0:
                            ev_dirty = cdirty[vp]
                            cres[vp] = False
                            cway[vp] = -1
                            ec += 1
                            epoch_mv[vp] = ec
                            journal.append(vp)
                        row[vw] = p
                        cway[p] = vw
                        cres[p] = True
                        cdirty[p] = True
                        cclk += 1
                        cstamp[p] = cclk
                        ec += 1
                        epoch_mv[p] = ec
                        journal.append(p)
                        ds.epoch_clock = ec
                        if ev_dirty:
                            ftl_write(t, vp)  # full program incl. GC
                            st.flash_write_pages += 1
                        bnd_n += 1
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # just inserted
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        ssd_w_n += 1
                        lat = stall + base + cache_idx + dram
                        if stall > 0.0:  # variable latency: histogram it
                            ssd_w_var_n += 1
                            lat_hist_w[lb(lat)] += 1
                        lat_sum += lat
                        lat_hit_acc += lat
                        t += lat
                        continue
                    # ---- flash read miss (Algorithm 1 park decision) ----
                    if block_route:
                        blk = l2p[p] // loc_div
                        ch = blk % n_ch
                        dd = (blk // n_ch) % DIES_PER_CHANNEL
                    else:
                        ch = (p * 1103515245 + 12345) % n_ch
                        dd = (p // n_ch) % DIES_PER_CHANNEL
                    die = chan_die[ch]
                    dv = die[dd]
                    bv = chan_bus[ch]
                    if ctx_on:  # inlined Channels.estimate
                        dw = dv - t
                        bw = bv - t
                        wait = dw if dw > bw else bw
                        est = (wait if wait > 0.0 else 0.0) + t_read
                    if dv > t:  # GC-pause attribution
                        gu = gc_until[ch][dd]
                        if gu > t:
                            gf = gc_from[ch][dd]
                            lo2 = t if t > gf else gf
                            hi2 = dv if dv < gu else gu
                            pause = hi2 - lo2
                            if pause > 0.0:
                                ds.gc_stall_events += 1
                                ds.gc_pause_ns_total += pause
                                if pause > ds.gc_pause_max_ns:
                                    ds.gc_pause_max_ns = pause
                    # inlined Channels.read
                    sensed = (dv if dv > t else t) + t_read
                    done = (sensed if sensed > bv else bv) + TRANSFER_NS
                    die[dd] = sensed
                    chan_bus[ch] = done
                    ds.chan_busy_ns += rd_busy
                    ds.flash_reads += 1
                    # inlined DataCache.insert(p, False) + write-back
                    # (KEEP IN SYNC with _insert_miss)
                    row = csets[p % n_sets]
                    vw = 0
                    vp = -1
                    vs = None
                    for w2 in range(ways):
                        q = row[w2]
                        if q < 0:
                            vw = w2
                            vp = -1
                            break
                        sq = cstamp[q]
                        if vs is None or sq < vs:
                            vs = sq
                            vw = w2
                            vp = q
                    ec = ds.epoch_clock
                    ev_dirty = False
                    if vp >= 0:
                        ev_dirty = cdirty[vp]
                        cres[vp] = False
                        cway[vp] = -1
                        ec += 1
                        epoch_mv[vp] = ec
                        journal.append(vp)
                    row[vw] = p
                    cway[p] = vw
                    cres[p] = True
                    cdirty[p] = False
                    cclk += 1
                    cstamp[p] = cclk
                    ec += 1
                    epoch_mv[p] = ec
                    journal.append(p)
                    ds.epoch_clock = ec
                    if ev_dirty:
                        ftl_write(t, vp)  # full program incl. GC
                        st.flash_write_pages += 1
                    if ctx_on and est > ctx_thr:
                        ctx_sw_n += 1
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # just inserted
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                            else:
                                acc[p] = cnt2
                        slow_n += 1
                        th.ready = done
                        th.replay = True
                        t += ctx_ns
                        k -= 1  # squashed access: replayed after wakeup
                        blocked = True
                        break
                    if promoting:
                        cnt2 = acc[p] + 1
                        if cnt2 >= promo_thr:  # just inserted
                            hflush()
                            ds.cache_clock = cclk
                            maybe_promote(p, t)
                            cclk = ds.cache_clock
                            hp_last = -1
                            bnd_n += 1
                        else:
                            acc[p] = cnt2
                    bnd_n += 1
                    lat = (done - t) + base + cache_idx + dram
                    miss_n += 1
                    lat_hist[lb(lat)] += 1
                    lat_sum += lat
                    lat_miss_acc += lat
                    t += lat
            else:
                # ================= write-log loop (-W) =================
                # KEEP IN SYNC with _inline_span's log loop. The active-
                # buffer probe is memoized for consecutive same-page
                # events (entry dicts mutate in place, so the memo stays
                # valid until a compaction swaps the dict or a promotion
                # runs — both reset it).
                an = ds.log_active_n
                lp_memo = -1
                e_memo = None
                for p, l, w, g in zip(pages[i:stop], lines[i:stop],
                                      writes[i:stop], gaps[i:stop]):
                    t += g
                    k += 1
                    if check_host and p in host:
                        if p != hp_last:
                            hbuf_app(p)  # deferred LRU move, see hflush
                            hp_last = p
                        if w:
                            host_w_n += 1
                        else:
                            host_r_n += 1
                        lat_sum += lat_host
                        lat_host_acc += lat_host
                        t += lat_host
                        continue
                    if p == lp_memo:
                        e = e_memo
                    else:
                        e = log_get(p)
                        lp_memo = p
                        e_memo = e
                    if w:
                        # cacheline write-log append -> compact if full
                        if e is None or l not in e:
                            if e is None:
                                e = log_active[p] = {}
                                e_memo = e
                            e[l] = True
                            # no epoch bump: new lines are absorbed by the
                            # vector path's per-chunk log overlay
                            logbits[p] = logbits[p] | (1 << l)
                            an += 1
                            if an >= log_cap:  # filled: drain old buffer
                                hflush()
                                ds.log_active_n = an
                                compact(t)
                                log_active = ds.log_active
                                log_get = log_active.get
                                an = ds.log_active_n
                                lp_memo = -1
                                e_memo = None
                                bnd_n += 1
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr and cres[p]:
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                lp_memo = -1
                                e_memo = None
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        ssd_w_n += 1
                        lat_sum += lat_log
                        lat_hit_acc += lat_log
                        t += lat_log
                        continue
                    # ---- read ----
                    if e is not None and l in e:
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr and cres[p]:
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                lp_memo = -1
                                e_memo = None
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        hit_log_n += 1
                        lat_sum += lat_log
                        lat_hit_acc += lat_log
                        t += lat_log
                        continue
                    if cres[p]:
                        cclk += 1
                        cstamp[p] = cclk  # LRU touch
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # resident
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                lp_memo = -1
                                e_memo = None
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        hit_cache_n += 1
                        lat_sum += lat_cache
                        lat_hit_acc += lat_cache
                        t += lat_cache
                        continue
                    # ---- flash read miss (Algorithm 1 park decision) ----
                    if block_route:
                        blk = l2p[p] // loc_div
                        ch = blk % n_ch
                        dd = (blk // n_ch) % DIES_PER_CHANNEL
                    else:
                        ch = (p * 1103515245 + 12345) % n_ch
                        dd = (p // n_ch) % DIES_PER_CHANNEL
                    die = chan_die[ch]
                    dv = die[dd]
                    bv = chan_bus[ch]
                    if ctx_on:  # inlined Channels.estimate
                        dw = dv - t
                        bw = bv - t
                        wait = dw if dw > bw else bw
                        est = (wait if wait > 0.0 else 0.0) + t_read
                    if dv > t:  # GC-pause attribution
                        gu = gc_until[ch][dd]
                        if gu > t:
                            gf = gc_from[ch][dd]
                            lo2 = t if t > gf else gf
                            hi2 = dv if dv < gu else gu
                            pause = hi2 - lo2
                            if pause > 0.0:
                                ds.gc_stall_events += 1
                                ds.gc_pause_ns_total += pause
                                if pause > ds.gc_pause_max_ns:
                                    ds.gc_pause_max_ns = pause
                    # inlined Channels.read
                    sensed = (dv if dv > t else t) + t_read
                    done = (sensed if sensed > bv else bv) + TRANSFER_NS
                    die[dd] = sensed
                    chan_bus[ch] = done
                    ds.chan_busy_ns += rd_busy
                    ds.flash_reads += 1
                    # inlined DataCache.insert(p, False) + write-back
                    # (KEEP IN SYNC with _insert_miss)
                    row = csets[p % n_sets]
                    vw = 0
                    vp = -1
                    vs = None
                    for w2 in range(ways):
                        q = row[w2]
                        if q < 0:
                            vw = w2
                            vp = -1
                            break
                        sq = cstamp[q]
                        if vs is None or sq < vs:
                            vs = sq
                            vw = w2
                            vp = q
                    ec = ds.epoch_clock
                    ev_dirty = False
                    if vp >= 0:
                        ev_dirty = cdirty[vp]
                        cres[vp] = False
                        cway[vp] = -1
                        ec += 1
                        epoch_mv[vp] = ec
                        journal.append(vp)
                    row[vw] = p
                    cway[p] = vw
                    cres[p] = True
                    cdirty[p] = False
                    cclk += 1
                    cstamp[p] = cclk
                    ec += 1
                    epoch_mv[p] = ec
                    journal.append(p)
                    ds.epoch_clock = ec
                    if ev_dirty:
                        ftl_write(t, vp)  # full program incl. GC
                        st.flash_write_pages += 1
                    lp_memo = -1  # write-back/GC may recycle log state
                    e_memo = None
                    if ctx_on and est > ctx_thr:
                        ctx_sw_n += 1
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # just inserted
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                            else:
                                acc[p] = cnt2
                        slow_n += 1
                        th.ready = done
                        th.replay = True
                        t += ctx_ns
                        k -= 1  # squashed access: replayed after wakeup
                        blocked = True
                        break
                    if promoting:
                        cnt2 = acc[p] + 1
                        if cnt2 >= promo_thr:  # just inserted
                            hflush()
                            ds.cache_clock = cclk
                            maybe_promote(p, t)
                            cclk = ds.cache_clock
                            hp_last = -1
                            bnd_n += 1
                        else:
                            acc[p] = cnt2
                    bnd_n += 1
                    lat = (done - t) + base + cache_idx + dram
                    miss_n += 1
                    lat_hist[lb(lat)] += 1
                    lat_sum += lat
                    lat_miss_acc += lat
                    t += lat
                ds.log_active_n = an
            ds.cache_clock = cclk
            if k:
                m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
            fused_n += k
            n_acc += k
            i += k
        th.i = i
        vrun[ti] += t - t0
        if i >= n and not th.replay:
            th.done = True
            n_alive -= 1
        else:
            heappush(wake_q, (th.ready, ti))
        cores[c] = t

    hflush()  # leave the host LRU in its authoritative final order
    # final flush of the localized accumulators
    st.n = n_acc
    st.host_r = host_r_n
    st.host_w = host_w_n
    st.hit_log = hit_log_n
    st.hit_cache = hit_cache_n
    st.miss_flash = miss_n
    st.ssd_w = ssd_w_n
    st.ssd_w_var = ssd_w_var_n
    st.ctx_switches = ctx_sw_n
    st.replays = replays_n
    st.lat_sum = lat_sum
    st.lat_host = lat_host_acc
    st.lat_hit = lat_hit_acc
    st.lat_miss = lat_miss_acc
    FUSED_STATS["fused_events"] += fused_n
    return cores
