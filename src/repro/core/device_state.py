"""Unified structure-of-arrays device state — the single source of truth.

Every piece of mutable CXL-SSD device state lives here, dense and indexed
by page, plus the few ordered structures the policies need (host-DRAM LRU
order, write-log insertion order, per-set slot tables). The policy/view
classes in ``ssd.py`` and BOTH replay engines read and mutate *these*
fields — there is no second copy anywhere. PR 2's shadow-mirror subclasses
(which re-applied every membership mutation into engine-private dense
arrays) are gone: the reference event loop and the batched engine literally
share the same arrays, so membership can never drift between them.

The arrays double as the batched engine's classification inputs:

  ``host.arr`` / ``cache_res``  membership (bool; gathered per chunk)
  ``log_bits``                  per-page 64-bit line-presence bitmask
  ``acc.arr``                   promotion counters (int64)
  ``cache_stamp``               LRU stamps (int64; a bulk LRU touch is ONE
                                scatter — last write wins reproduces the
                                reference's last-occurrence move order)
  ``page_epoch``                per-page version counters driving the
                                cross-quantum classification cache

Epoch discipline (see engine.py): every *membership* mutation — cache
insert/evict/remove, host promote/demote, compaction floods — calls
``bump``/``bump_list``; write-log *appends* deliberately do not (line
presence only grows between compactions and is absorbed by the engine's
log overlay instead). The journal names the pages bumped by the boundary
event in flight so the engine can fold them back into a live
classification cache mid-quantum.

Scalar-hot fields use ``memoryview`` mirrors (Python-int get/set is ~4x
cheaper than NumPy scalar indexing); the ndarray views are what the vector
path fancy-indexes. Channel/die busy timelines are plain Python float
lists: they are only ever touched scalar-wise (per flash op), where lists
beat any NumPy representation.

The address-resolution tables both engines consult live here too: the
block FTL's ``flash.l2p`` mapping (physical service-path routing — every
read/program derives its channel/die from the block the FTL placed the
page in) and ``gc_die_until``, the per-die horizon up to which a die's
busy window is GC-induced (drives the host-observed GC-pause attribution
in Stats).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List

import numpy as np

from repro.configs.base import SimConfig

DIES_PER_CHANNEL = 64  # Table II: 8 chips/channel x 8 dies/chip


class HostLru(OrderedDict):
    """Host-DRAM page tier: authoritative LRU order (dict order) plus the
    dense membership mirror and epoch bumps on membership changes."""

    def __init__(self, state: "DeviceState", page_space: int):
        super().__init__()
        self.arr = np.zeros(page_space, bool)
        self._mv = memoryview(self.arr)
        self._ds = state

    def __setitem__(self, page, value) -> None:
        super().__setitem__(page, value)
        self._mv[page] = True
        self._ds.bump(page)

    def popitem(self, last: bool = True):
        page, value = super().popitem(last)
        self._mv[page] = False
        self._ds.bump(page)
        return page, value


class PromoCounts:
    """Dense per-page promotion counters with the dict API the promotion
    policy uses (.get / item assignment)."""

    __slots__ = ("arr", "_mv")

    def __init__(self, page_space: int):
        self.arr = np.zeros(page_space, np.int64)
        self._mv = memoryview(self.arr)

    def get(self, page: int, default: int = 0) -> int:
        return self._mv[page]

    def __setitem__(self, page: int, value: int) -> None:
        self._mv[page] = value


class DeviceState:
    """All mutable device state for one simulated CXL-SSD."""

    __slots__ = (
        "page_space",
        # epochs
        "page_epoch", "epoch_mv", "epoch_clock", "journal",
        # host tier
        "host",
        # SSD DRAM page cache (set-associative, stamp-LRU)
        "cache_res", "cache_res_mv", "cache_dirty", "cache_dirty_mv",
        "cache_stamp", "cache_stamp_mv", "cache_clock",
        "cache_sets", "cache_way", "cache_ways", "cache_n_sets",
        # cacheline write log (double-buffered)
        "log_bits", "log_active", "log_old", "log_active_n", "log_cap",
        "log_compactions", "log_flushed_pages", "log_flushed_lines",
        # flash channels / dies
        "chan_bus", "chan_die", "chan_busy_ns",
        "flash_reads", "flash_writes", "gc_events", "gc_migrated_pages",
        # GC-pause visibility: the last GC-carved busy window per die
        # ([gc_die_from, gc_die_until]; contiguous GC extensions merge),
        # plus the host-observed attribution counters (bumped at every
        # flash-read issue whose wait overlaps such a window — identically
        # by both engines; see Channels.read and the inline span's
        # mirrored sites). Recording the window START keeps wait that was
        # already queued behind host programs out of the attribution.
        "gc_die_from", "gc_die_until", "gc_pause_ns_total",
        "gc_pause_max_ns", "gc_stall_events",
        # FTL: legacy free-page accounting + block-granular backend state
        "ftl_total", "ftl_used", "flash",
        # promotion counters
        "acc",
        # fault / recovery bookkeeping (core/faults.py; all zero and
        # untouched when no FaultModel is attached)
        "ft_retry_reads", "ft_retry_steps", "ft_uncorrectable",
        "ft_outage_events", "ft_outage_ns",
        "ft_die_failures", "ft_remapped_pages", "ft_bad_blocks",
        "ft_power_losses", "ft_recovery_ns_total", "ft_recovery_ns_max",
        "ft_replayed_pages", "ft_lost_dirty_pages", "ft_lost_inflight",
        "ft_degraded", "ft_write_errors",
        # die-level QoS (core/qos.py). gc_windows / gc_susp_left are
        # maintained unconditionally by the FTL's window carves (cheap:
        # one int write per NEW window, not per read); the remaining
        # counters are only touched by an attached QosModel.
        "gc_windows", "gc_susp_left",
        "gc_suspends", "gc_resumes", "gc_resume_ns_total",
        "gc_pause_avoided_ns",
        "rp_bypasses", "rp_wait_saved_ns", "qos_die_wait_max_ns",
        # latency provenance (core/obs.py): attached ObsModel or None.
        # Lives on the state object so shared-call sites (flash.py GC
        # carves, simulator compaction) can emit events without a back-
        # pointer to the Machine; None on every zero-obs run.
        "obs",
    )

    def __init__(self, cfg: SimConfig, page_space: int):
        self.page_space = page_space
        # --- epoch board ---
        self.page_epoch = np.zeros(page_space, np.int64)
        self.epoch_mv = memoryview(self.page_epoch)
        self.epoch_clock = 0
        self.journal: List[int] = []
        # --- host tier ---
        self.host = HostLru(self, page_space)
        # --- data cache: per-page membership/dirty/stamp arrays + per-set
        # slot tables. LRU order is the stamp order (a fresh monotone stamp
        # per touch/insert reproduces OrderedDict move-to-end semantics
        # exactly); the victim of a full set is its min-stamp slot. ---
        ways = max(cfg.cache_ways, 1)
        n_sets = max(cfg.cache_pages // ways, 1)
        self.cache_ways = ways
        self.cache_n_sets = n_sets
        self.cache_res = np.zeros(page_space, bool)
        self.cache_res_mv = memoryview(self.cache_res)
        self.cache_dirty = np.zeros(page_space, bool)
        self.cache_dirty_mv = memoryview(self.cache_dirty)
        self.cache_stamp = np.zeros(page_space, np.int64)
        self.cache_stamp_mv = memoryview(self.cache_stamp)
        self.cache_clock = 0
        self.cache_sets = [[-1] * ways for _ in range(n_sets)]
        self.cache_way = [-1] * page_space
        # --- write log (allocated only when the variant enables it) ---
        if cfg.enable_write_log:
            self.log_bits = np.zeros(page_space, np.uint64)
            self.log_active = {}
            self.log_old = {}
            self.log_active_n = 0
            self.log_cap = max(cfg.log_entries // 2, 16)  # per buffer
        else:
            self.log_bits = None
            self.log_active = None
            self.log_old = None
            self.log_active_n = 0
            self.log_cap = 0
        self.log_compactions = 0
        self.log_flushed_pages = 0
        self.log_flushed_lines = 0
        # --- flash timing state ---
        self.chan_bus = [0.0] * cfg.n_channels
        self.chan_die = [[0.0] * DIES_PER_CHANNEL for _ in range(cfg.n_channels)]
        self.chan_busy_ns = 0.0
        self.flash_reads = 0
        self.flash_writes = 0
        self.gc_events = 0
        self.gc_migrated_pages = 0
        self.gc_die_from = [[0.0] * DIES_PER_CHANNEL
                            for _ in range(cfg.n_channels)]
        self.gc_die_until = [[0.0] * DIES_PER_CHANNEL
                             for _ in range(cfg.n_channels)]
        self.gc_pause_ns_total = 0.0
        self.gc_pause_max_ns = 0.0
        self.gc_stall_events = 0
        # --- FTL ---
        self.ftl_total = max(cfg.n_flash_pages, 1)
        self.ftl_used = int(self.ftl_total * cfg.gc_threshold)  # preconditioned
        if cfg.ftl_backend == "block":
            from repro.core.flash import FlashState

            self.flash = FlashState(page_space, cfg.pages_per_block,
                                    cfg.op_ratio, cfg.hotcold)
        elif cfg.ftl_backend == "legacy":
            self.flash = None
        else:
            raise ValueError(
                f"unknown SimConfig.ftl_backend: {cfg.ftl_backend!r}")
        # --- promotion counters ---
        self.acc = PromoCounts(page_space)
        # --- fault / recovery counters (folded into Stats.finalize) ---
        self.ft_retry_reads = 0       # reads that engaged the retry ladder
        self.ft_retry_steps = 0       # total ladder steps across all reads
        self.ft_uncorrectable = 0     # reads past the ladder (ECC poison)
        self.ft_outage_events = 0
        self.ft_outage_ns = 0.0
        self.ft_die_failures = 0
        self.ft_remapped_pages = 0    # valid pages migrated off dead dies
        self.ft_bad_blocks = 0
        self.ft_power_losses = 0
        self.ft_recovery_ns_total = 0.0
        self.ft_recovery_ns_max = 0.0
        self.ft_replayed_pages = 0    # durable log lines replayed to flash
        self.ft_lost_dirty_pages = 0  # volatile dirty cache pages dropped
        self.ft_lost_inflight = 0     # dies with programs cut mid-flight
        self.ft_degraded = 0          # 1 once spares exhaust: read-only
        self.ft_write_errors = 0      # host-visible write failures while
        #                               degraded (the RuntimeError is gone)
        # --- die-level QoS bookkeeping (folded into Stats.finalize) ---
        self.gc_windows = 0           # distinct GC windows carved (all runs)
        # Per-die residual suspend budget for the CURRENT window; refilled
        # to cfg.gc_suspend_max whenever a die carves a new window, so the
        # testable bound is gc_suspends <= gc_suspend_max * gc_windows.
        self.gc_susp_left = [[0] * DIES_PER_CHANNEL
                             for _ in range(cfg.n_channels)]
        self.gc_suspends = 0
        self.gc_resumes = 0           # == suspends today (every suspend
        #                               schedules exactly one resume)
        self.gc_resume_ns_total = 0.0
        self.gc_pause_avoided_ns = 0.0  # pause the read would have eaten
        self.rp_bypasses = 0          # reads scheduled ahead of die backlog
        self.rp_wait_saved_ns = 0.0
        self.qos_die_wait_max_ns = 0.0  # max die backlog seen at QoS'd
        #                                 host-read issue (queue occupancy)
        self.obs = None               # ObsModel when cfg.obs.enabled

    # ---- epoch bumps (called by the ssd.py views and HostLru) ----
    def bump(self, page: int) -> None:
        c = self.epoch_clock + 1
        self.epoch_clock = c
        self.epoch_mv[page] = c
        self.journal.append(page)

    def bump_list(self, pages: list) -> None:
        c = self.epoch_clock + len(pages)
        self.epoch_clock = c
        self.page_epoch[pages] = c
        self.journal.extend(pages)

    def discrete_signature(self) -> tuple:
        """Bit-comparable snapshot of every DISCRETE piece of device
        state: tier membership and order, cache tags/stamps, write-log
        contents, FTL mapping/wear/frontiers, and integer event
        counters. Float timelines (channel/die busy-until, GC pause
        nanoseconds) are deliberately excluded — they are the APPROXIMATE
        tier of the turbo engine's contract; everything returned here
        must be `==` across all three engines (the exact tier, enforced
        by tests/test_engine_turbo.py)."""
        fl = self.flash
        flash_sig = None
        if fl is not None:
            flash_sig = (
                fl.l2p.tobytes(), fl.p2l.tobytes(), fl.pvalid.tobytes(),
                fl.blk_valid.tobytes(), fl.blk_state.tobytes(),
                fl.blk_seal.tobytes(), fl.blk_erase.tobytes(),
                fl.blk_gc.tobytes(), tuple(fl.free), fl.seal_seq,
                fl.host_blk, fl.host_slot, fl.gc_blk, fl.gc_slot,
                fl.hot_blk, fl.hot_slot,
            )
        log_sig = None
        if self.log_bits is not None:
            # dict iteration order is insertion order — part of the
            # compaction contract, so it participates in the signature
            log_sig = (self.log_bits.tobytes(),
                       tuple(self.log_active.items()),
                       tuple(self.log_old.items()),
                       self.log_active_n)
        return (
            self.page_epoch.tobytes(), self.epoch_clock,
            tuple(self.host),  # host LRU order, coldest first
            self.cache_res.tobytes(), self.cache_dirty.tobytes(),
            self.cache_stamp.tobytes(), self.cache_clock,
            tuple(map(tuple, self.cache_sets)), tuple(self.cache_way),
            self.acc.arr.tobytes(),
            self.flash_reads, self.flash_writes,
            self.gc_events, self.gc_migrated_pages, self.gc_stall_events,
            self.ftl_used, self.log_compactions, self.log_flushed_pages,
            self.log_flushed_lines,
            self.ft_retry_reads, self.ft_uncorrectable, self.ft_die_failures,
            self.ft_remapped_pages, self.ft_bad_blocks, self.ft_power_losses,
            self.gc_suspends, self.gc_resumes, self.rp_bypasses,
            flash_sig, log_sig,
        )
