"""SkyByte system simulator — multi-core trace replay against the CXL-SSD.

Reproduces the paper's evaluation harness (§V): per-thread off-chip access
traces are replayed on N cores against the device model in ssd.py, with the
three SkyByte mechanisms as selectable flags (SimConfig.variant), exactly
mirroring the §VI-A ablation grid:

  Base-CSSD    — page-granular DRAM cache only (write-allocate, write-back)
  SkyByte-C    — + coordinated context switch (Algorithm 1 trigger)
  SkyByte-P    — + adaptive page promotion to host DRAM
  SkyByte-W    — + cacheline write log & compaction
  -CP/-WP/Full — combinations
  DRAM-Only    — ideal infinite host DRAM

Timing model (request-event level; deltas vs the paper's cycle-accurate
MacSim are confined to sub-100ns effects and documented in DESIGN.md):
  host DRAM hit   : host_dram_ns
  SSD log hit     : cxl + log_index + ssd_dram
  SSD cache hit   : cxl + cache_index + ssd_dram
  SSD miss        : cxl + cache_index + channel queue + t_read + ssd_dram
  context switch  : ctx_switch_ns charged to the core; blocked thread
                    becomes runnable at flash completion; the re-issued
                    (replayed) access is charged as an SSD DRAM hit, and
                    the squashed original is excluded from AMAT (§VI-D).

Scheduling policies: RR / RANDOM / CFS (default, vruntime-based). The
scheduler state is dense (per-thread ready/vruntime/last-sched arrays);
candidate selection is one masked argmin per quantum, with done threads
parked at +inf. Tie-breaking (first minimal thread index) and the RANDOM
policy's RNG stream are identical to the historical object scan.

Two replay engines share the scheduler AND one authoritative
``DeviceState`` (SimConfig.engine):
  "reference" — the original pure-Python per-event loop. Ground truth and
                parity oracle: ``Machine.serve()`` exists for it alone.
  "batched"   — the vectorized fast path in engine.py: resolves runs of
                state-stable accesses with NumPy bulk passes over the
                shared state arrays and executes every state-changing
                boundary through its own exact transcription.
Both produce identical Stats (see tests/test_engine.py).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import os
import random
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DeviceState
from repro.core.flash import BlockFtl
from repro.core.ssd import Channels, DataCache, Ftl, WriteLog
from repro.core.traces import gen_traces

PAGE = 4096
LINE = 64

# ---------------------------------------------------------------------------
# Per-request latency distribution. Most retired requests have one of a
# handful of *constant* latencies (host DRAM hit, log hit, cache hit, log
# append) whose exact values and counts the Stats counters already carry;
# only flash read misses and MSHR-stalled Base-CSSD write misses vary.
# Those variable latencies go into a log-scale histogram (8 sub-bins per
# octave, ~4.5% bin width), and p50/p95/p99 are computed exactly over the
# merged multiset — so the common percentiles usually land on a constant
# class and are reported exactly, while deep-tail values are quantized to
# the bin edge. Both engines bump the histogram at the same retire points
# with identical latencies, so it is bit-identical by construction.
# ---------------------------------------------------------------------------

_LAT_NBINS = 512

# Canonical replay-engine names (SimConfig.engine / REPRO_SIM_ENGINE /
# benchmarks.run --engine / scripts/paired_bench.py --engines all validate
# against this tuple — keep it the single source of truth):
#   reference — per-event Python loop (ground truth)
#   batched   — vectorized + fused fast path, bit-exact vs reference
#   turbo     — opt-in fast-math engine (core/turbo.py): discrete state
#               bit-exact, float timelines within SimConfig.turbo_rtol
ENGINES = ("reference", "batched", "turbo")


def _lat_bin(lat: float) -> int:
    """Histogram bin of one latency (ns): 8 log-scale sub-bins/octave."""
    v = int(lat)
    if v < 8:
        return v if v > 0 else 0
    e = v.bit_length() - 1
    b = (e << 3) | ((v >> (e - 3)) & 7)
    return b if b < _LAT_NBINS else _LAT_NBINS - 1


def _lat_bin_edge(b: int) -> float:
    """Lower edge (ns) of histogram bin b — the reported tail value."""
    if b < 8:
        return float(b)
    e = b >> 3
    return float((1 << e) + ((b & 7) << (e - 3)))


def percentiles_from_items(items, total: int,
                           qs=(0.50, 0.95, 0.99)) -> List[float]:
    """Exact percentiles over a (value, count) multiset.

    The one shared walk behind lat_p* / lat_read_p* and every
    per-component percentile in core/obs.py (figure modules consume the
    exported fields rather than re-deriving bins locally). rank =
    max(ceil(q*total), 1) over the value-sorted multiset — duplicate
    constant-latency entries merge under the sort, so the result is
    bit-identical to the historical inline loop in Stats.finalize."""
    srt = sorted(it for it in items if it[1] > 0)
    out: List[float] = []
    for q in qs:
        if not total:
            out.append(0.0)
            continue
        rank = max(int(np.ceil(q * total)), 1)
        cum = 0
        val = srt[-1][0] if srt else 0.0
        for v, c in srt:
            cum += c
            if cum >= rank:
                val = v
                break
        out.append(float(val))
    return out


class Stats:
    __slots__ = (
        "n", "host_r", "host_w", "hit_log", "hit_cache", "miss_flash", "ssd_w",
        "lat_sum", "lat_host", "lat_hit", "lat_miss", "ctx_switches",
        "flash_write_pages", "gc_events", "gc_migrated_pages", "waf",
        "gc_pause_ns_total", "gc_pause_max_ns", "gc_stall_events",
        "promotions", "demotions",
        "exec_ns", "busy_ns", "replays",
        "lat_p50_ns", "lat_p95_ns", "lat_p99_ns",
        # read-only percentiles (host reads + log/cache read hits + flash
        # read misses; posted writes and their slot stalls excluded).
        # Read priority deliberately trades write tail for read tail, so
        # the mixed percentiles above cannot see its win.
        "lat_read_p50_ns", "lat_read_p95_ns", "lat_read_p99_ns",
        # variable-latency bookkeeping (the histograms are engine-internal:
        # the percentiles above are their exported summary; lat_hist holds
        # flash READ misses, lat_hist_w the variable write-slot stalls)
        "ssd_w_var", "lat_hist", "lat_hist_w",
        # fault / recovery (folded from DeviceState.ft_*; all zero unless
        # a FaultConfig knob is on)
        "retry_reads", "retry_steps", "uncorrectable_reads", "uber",
        "outage_events", "outage_ns_total",
        "die_failures", "remapped_pages", "bad_blocks",
        "power_loss_events", "recovery_ns_total", "recovery_ns_max",
        "replayed_pages", "lost_dirty_pages", "lost_inflight",
        "degraded_mode", "degraded_writes",
        # die-level QoS (folded from DeviceState; gc_windows is live on
        # every run, the rest only move with a QosModel attached)
        "gc_windows", "gc_suspends", "gc_resumes", "gc_resume_ns_total",
        "gc_pause_avoided_ns",
        "rp_bypasses", "rp_wait_saved_ns", "qos_die_wait_max_ns",
        # fast-math turbo engine drift accounting (core/turbo.py): the
        # engine's a-priori bound on the relative error of the float
        # timelines vs the reference chains (max/mean over threads).
        # Exactly 0 for the reference/batched engines and for turbo runs
        # that refused onto the exact fallback path.
        "turbo_drift_max", "turbo_drift_mean",
    )

    def __init__(self):
        for f in self.__slots__:
            setattr(self, f, 0)
        self.lat_hist = np.zeros(_LAT_NBINS, np.int64)
        self.lat_hist_w = np.zeros(_LAT_NBINS, np.int64)

    def as_dict(self) -> Dict[str, Any]:
        d = {f: getattr(self, f) for f in self.__slots__
             if f not in ("lat_hist", "lat_hist_w")}
        n = max(self.n, 1)
        d["amat_ns"] = self.lat_sum / n
        d["flash_write_bytes"] = self.flash_write_pages * PAGE
        return d

    def finalize(self, cfg: SimConfig, ds: DeviceState) -> None:
        """Fold device-state accounting into the exported stats: WAF,
        migrated pages, and the exact latency percentiles. Pure function
        of counters both engines produce identically."""
        self.gc_migrated_pages = ds.gc_migrated_pages
        # host-observed GC pauses: accumulated at every flash-read issue
        # that queued behind a GC-carved die window (Channels.read + the
        # inline span's mirrored sites — identical order in both engines)
        self.gc_pause_ns_total = ds.gc_pause_ns_total
        self.gc_pause_max_ns = ds.gc_pause_max_ns
        self.gc_stall_events = ds.gc_stall_events
        # die-level QoS counters (core/qos.py; zero when QoS off)
        self.gc_windows = ds.gc_windows
        self.gc_suspends = ds.gc_suspends
        self.gc_resumes = ds.gc_resumes
        self.gc_resume_ns_total = ds.gc_resume_ns_total
        self.gc_pause_avoided_ns = ds.gc_pause_avoided_ns
        self.rp_bypasses = ds.rp_bypasses
        self.rp_wait_saved_ns = ds.rp_wait_saved_ns
        self.qos_die_wait_max_ns = ds.qos_die_wait_max_ns
        fw = ds.flash_writes
        self.waf = (fw + ds.gc_migrated_pages) / fw if fw else 1.0
        # fault / recovery counters (core/faults.py; zero when faults off)
        self.retry_reads = ds.ft_retry_reads
        self.retry_steps = ds.ft_retry_steps
        self.uncorrectable_reads = ds.ft_uncorrectable
        fr = ds.flash_reads
        self.uber = ds.ft_uncorrectable / fr if fr else 0.0
        self.outage_events = ds.ft_outage_events
        self.outage_ns_total = ds.ft_outage_ns
        self.die_failures = ds.ft_die_failures
        self.remapped_pages = ds.ft_remapped_pages
        self.bad_blocks = ds.ft_bad_blocks
        self.power_loss_events = ds.ft_power_losses
        self.recovery_ns_total = ds.ft_recovery_ns_total
        self.recovery_ns_max = ds.ft_recovery_ns_max
        self.replayed_pages = ds.ft_replayed_pages
        self.lost_dirty_pages = ds.ft_lost_dirty_pages
        self.lost_inflight = ds.ft_lost_inflight
        self.degraded_mode = ds.ft_degraded
        self.degraded_writes = ds.ft_write_errors
        lat_log = cfg.cxl_protocol_ns + cfg.log_index_ns + cfg.ssd_dram_ns
        lat_cache = cfg.cxl_protocol_ns + cfg.cache_index_ns + cfg.ssd_dram_ns
        ssd_w_const = self.ssd_w - self.ssd_w_var
        # read-side classes: host-DRAM reads, log/cache read hits, and the
        # flash-read-miss histogram
        r_items = [
            (cfg.host_dram_ns, self.host_r),
            (lat_log, self.hit_log),
            (lat_cache, self.hit_cache),
        ]
        r_items.extend((_lat_bin_edge(b), int(c))
                       for b, c in enumerate(self.lat_hist.tolist()) if c)
        # write-side classes: host-DRAM writes, constant-latency posted
        # writes (log-indexed when the write log is on, cache-indexed
        # otherwise), and the variable write-slot-stall histogram
        w_items = [
            (cfg.host_dram_ns, self.host_w),
            (lat_log if cfg.enable_write_log else lat_cache, ssd_w_const),
        ]
        w_items.extend((_lat_bin_edge(b), int(c))
                       for b, c in enumerate(self.lat_hist_w.tolist()) if c)
        n_reads = self.host_r + self.hit_log + self.hit_cache \
            + self.miss_flash
        # the combined list is the same multiset the pre-split histogram
        # produced (duplicate constant-latency entries merge under the
        # sort), so lat_p* stay bit-identical to the one-histogram era
        for fields, items, total in (
            (("lat_p50_ns", "lat_p95_ns", "lat_p99_ns"),
             r_items + w_items, self.n),
            (("lat_read_p50_ns", "lat_read_p95_ns", "lat_read_p99_ns"),
             r_items, n_reads),
        ):
            for field, val in zip(fields,
                                  percentiles_from_items(items, total)):
                setattr(self, field, val)


class Thread:
    __slots__ = ("tid", "page", "line", "write", "gap64", "i", "n",
                 "ready", "replay", "done")

    def __init__(self, tid: int, trace: Dict):
        self.tid = tid
        self.page = trace["page"]
        self.line = trace["line"]
        self.write = trace["write"]
        # Traces carry float32 gaps; accumulate core time in float64 — a
        # float32 timeline loses whole-ns resolution past ~16ms of sim time
        # (1 ulp at 1e9 ns is 64 ns, bigger than every SSD-DRAM latency).
        self.gap64 = np.asarray(trace["gap_ns"], dtype=np.float64)
        self.i = 0
        self.n = len(self.page)
        self.ready = 0.0
        self.replay = False
        self.done = False


class Machine:
    """Policy layer over one DeviceState: promotion/demotion, compaction,
    eviction write-back, and the per-event request oracle ``serve()``.

    Both engines run on a Machine (the batched engine's BatchedMachine
    subclass only adds classification-cache bookkeeping); all device state
    lives in ``self.state`` and is shared — by construction — between the
    reference loop and the batched fast path."""

    def __init__(self, cfg: SimConfig, seed: int = 0, page_space: int = 0):
        self.cfg = cfg
        if page_space <= 0:
            page_space = max(cfg.n_flash_pages, 1)
        self.state = DeviceState(cfg, page_space)
        self.channels = Channels(cfg, self.state)
        # block-granular FTL (core/flash.py) unless the legacy free-page
        # counter is requested; both expose on_flash_write(now, page),
        # which performs the ENTIRE host program (destination resolution,
        # bus/die timing, mapping update, GC). ``loc_of`` is the service-
        # path address resolver every read consults: the FTL's physical
        # placement under the block backend, the logical hash stripe
        # under legacy.
        if self.state.flash is not None:
            self.ftl = BlockFtl(cfg, self.state, self.channels)
            self.loc_of = self.ftl.phys_loc
        else:
            self.ftl = Ftl(cfg, self.state, self.channels)
            self.loc_of = self.channels.logical_loc
        # fault injection (core/faults.py): attach only when some knob is
        # on, so the zero-fault hot path keeps its is-None fast test and
        # identical cell cache keys modulo the (default) fault group
        if cfg.fault.enabled:
            from repro.core.faults import FaultModel

            self.fault = FaultModel(cfg, self.state, self.channels, self.ftl)
            self.channels.fault = self.fault
        else:
            self.fault = None
        # die-level QoS (core/qos.py): same attach-only-when-on contract
        # as faults — zero-QoS configs construct no QosModel and the read
        # path keeps its is-None fast test. Config validation guarantees
        # fault and qos are never both attached.
        if cfg.qos_enabled:
            from repro.core.qos import QosModel

            self.qos = QosModel(cfg, self.state, self.channels)
            self.channels.qos = self.qos
        else:
            self.qos = None
        # latency provenance (core/obs.py): same attach-only-when-on
        # contract — obs-active cells are a conflict class (run_fused
        # refuses; batched_quantum and the reference loop share the one
        # staged read dispatch), zero-obs runs construct nothing and pay
        # one is-None test per retire site. Lives on the state object
        # too so flash-layer GC carves and compaction can emit events.
        if cfg.obs.enabled:
            from repro.core.obs import ObsModel

            self.obs = ObsModel(cfg)
            self.channels.obs = self.obs
            self.state.obs = self.obs
        else:
            self.obs = None
        self.cache = DataCache(cfg, self.state)
        self.log = WriteLog(cfg, self.state) if cfg.enable_write_log else None
        self.host = self.state.host
        self.host_cap = max(cfg.host_pages, 1)
        self.acc_count = self.state.acc
        self.stats = Stats()
        self.rng = random.Random(seed)

    # ---- promotion (§III-C; §VI-H alternative policies) ----
    def _maybe_promote(self, page: int, now: float) -> None:
        cfg = self.cfg
        if not cfg.enable_promotion:
            return
        if cfg.promo_policy == "tpp":
            # TPP: periodic sampling — hotness observed only 1/4 of the time
            if self.rng.random() < 0.75:
                return
            c = self.acc_count.get(page, 0) + 1
            self.acc_count[page] = c
            if c < max(cfg.promote_threshold // 4, 2) or page in self.host:
                return
        elif cfg.promo_policy == "astriflash":
            # AstriFlash: host DRAM as a page cache of the SSD — every
            # touched page is installed (no hotness filter)
            if page in self.host:
                return
        else:
            c = self.acc_count.get(page, 0) + 1
            self.acc_count[page] = c
            if c < cfg.promote_threshold or page in self.host:
                return
        # paper: only pages resident in SSD DRAM cache are candidates
        if self.cache.lookup(page, touch=False) is None:
            return
        if len(self.host) >= self.host_cap:
            # Linux-reclaim-style: demote the coldest (LRU order) page
            cold, _ = self.host.popitem(last=False)
            self.stats.demotions += 1
            self.acc_count[cold] = 0  # restart hotness tracking (no ping-pong)
            ev = self.cache.insert(cold, True)  # back to SSD DRAM, dirty
            self._handle_evict(ev, now)
        self.host[page] = True
        self.cache.remove(page)
        self.stats.promotions += 1

    def _handle_evict(self, ev, now: float) -> None:
        if ev is not None and ev[1]:  # dirty page writeback
            self.ftl.on_flash_write(now, ev[0])  # timing + mapping + GC
            self.stats.flash_write_pages += 1

    # ---- compaction (§III-B) ----
    def _compact(self, now: float) -> None:
        """Background log compaction. Flushes are *staggered* so compaction
        uses at most ~half of each channel's bandwidth — the paper drains
        the old log off the critical path (146 us per compaction step,
        §III-B) rather than monopolizing the flash channels; foreground
        reads must keep making progress between compaction programs."""
        log = self.log
        st = self.state
        old = log.swap_for_compaction()
        for page, lines in old.items():
            if self.cache.lookup(page, touch=False) is None:
                # coalescing-buffer fill from the page's current location
                # (device-internal: no thread blocks on it -> no GC-pause
                # attribution)
                self.channels.read(*self.loc_of(page), now, gc_attr=False)
            self.ftl.on_flash_write(now, page)
            self.stats.flash_write_pages += 1
            st.log_flushed_pages += 1
            st.log_flushed_lines += len(lines)
        log.finish_compaction()
        o = st.obs
        if o is not None:
            o.on_compaction(now, len(old))

    # ---- request service ----
    def serve(self, page: int, line: int, is_write: bool, now: float, wslots):
        """Returns (latency_ns, blocked_until or None, amat_class).

        The reference engine's per-event oracle (the batched engine
        transcribes every case into its own paths and never calls this).
        blocked_until is set when the coordinated context switch fires:
        the thread parks until flash completion and replays the access.
        ``wslots``: per-core in-flight posted-write completion times (models
        the MSHR/write-buffer bound max_outstanding — a Base-CSSD write miss
        fetches its page in the background and only stalls the core when all
        slots are occupied).
        """
        cfg = self.cfg
        st = self.stats
        if cfg.dram_only:
            cls = "host_w" if is_write else "host_r"
            return cfg.host_dram_ns, None, cls

        if page in self.host:
            self.host.move_to_end(page)
            return cfg.host_dram_ns, None, ("host_w" if is_write else "host_r")

        base = cfg.cxl_protocol_ns
        if is_write:
            if self.log is not None:
                lat = base + cfg.log_index_ns + cfg.ssd_dram_ns
                full = self.log.append(page, line)
                if self.cache.lookup(page, touch=False) is not None:
                    pass  # parallel in-place cache update (kept consistent)
                if full:
                    self._compact(now)
                self._maybe_promote(page, now)
                return lat, None, "ssd_w"
            # Base-CSSD: write-allocate into the page cache (posted store;
            # background page fetch occupies a write slot)
            hit = self.cache.lookup(page)
            if hit is not None:
                self.cache.mark_dirty(page)
                self._maybe_promote(page, now)
                return base + cfg.cache_index_ns + cfg.ssd_dram_ns, None, "ssd_w"
            stall = 0.0
            if len(wslots) >= cfg.max_outstanding:
                oldest = min(wslots)
                wslots.remove(oldest)
                stall = max(0.0, oldest - now)
            # background fetch (posted store): occupies a write slot, the
            # core never waits on the read itself -> no GC-pause books
            done = self.channels.read(*self.loc_of(page), now + stall,
                                      gc_attr=False)
            wslots.append(done)
            ev = self.cache.insert(page, True)
            self._handle_evict(ev, now)
            self._maybe_promote(page, now)
            lat = stall + base + cfg.cache_index_ns + cfg.ssd_dram_ns
            if stall > 0.0:  # variable latency: tail-histogram it
                st.ssd_w_var += 1
                st.lat_hist_w[_lat_bin(lat)] += 1
                o = self.obs
                if o is not None:  # KEEP IN SYNC with engine write-miss
                    o.commit_write_stall(lat, stall, now)
            return lat, None, "ssd_w"

        # ---- read ----
        if self.log is not None and self.log.lookup(page, line):
            self._maybe_promote(page, now)
            return base + cfg.log_index_ns + cfg.ssd_dram_ns, None, "hit_log"
        if self.cache.lookup(page) is not None:
            self._maybe_promote(page, now)
            return base + cfg.cache_index_ns + cfg.ssd_dram_ns, None, "hit_cache"
        # SSD DRAM miss -> flash: service latency queues on the page's
        # PHYSICAL placement (the die the FTL put it on; legacy = the
        # logical hash stripe)
        ch, d = self.loc_of(page)
        if cfg.enable_ctx_switch:
            est = self.channels.estimate(ch, d, now)
            if est > cfg.ctx_threshold_ns:
                done = self.channels.read(ch, d, now)
                ev = self.cache.insert(page, False)
                self._handle_evict(ev, now)
                st.ctx_switches += 1
                self._maybe_promote(page, now)
                o = self.obs
                if o is not None:  # parked: the squashed access never
                    o.on_park()    # retires, drop the staged read
                return 0.0, done, "switched"
        done = self.channels.read(ch, d, now)
        ev = self.cache.insert(page, False)
        self._handle_evict(ev, now)
        self._maybe_promote(page, now)
        lat = (done - now) + base + cfg.cache_index_ns + cfg.ssd_dram_ns
        o = self.obs
        if o is not None:  # KEEP IN SYNC with engine read-miss sites
            o.commit_read_miss(lat)
        return lat, None, "miss_flash"


_CLS_LAT = ("host_r", "host_w", "hit_log", "hit_cache", "miss_flash", "ssd_w")


def _record(st: Stats, cls: str, lat: float) -> None:
    """Charge one retired request to the Stats counters."""
    st.n += 1
    st.lat_sum += lat
    if cls == "host_r":
        st.host_r += 1
        st.lat_host += lat
    elif cls == "host_w":
        st.host_w += 1
        st.lat_host += lat
    elif cls == "hit_log":
        st.hit_log += 1
        st.lat_hit += lat
    elif cls == "hit_cache":
        st.hit_cache += 1
        st.lat_hit += lat
    elif cls == "ssd_w":
        st.ssd_w += 1
        st.lat_hit += lat
    else:
        st.miss_flash += 1
        st.lat_miss += lat
        st.lat_hist[_lat_bin(lat)] += 1


def _replay_prologue(m: Machine, cfg: SimConfig, th: Thread, t: float):
    """Re-issue of a context-switched access (§III-A 4): charged as an SSD
    DRAM hit; the squashed original was excluded from AMAT. Returns the new
    (i, t) after consuming the replayed access."""
    th.replay = False
    lat = cfg.cxl_protocol_ns + cfg.cache_index_ns + cfg.ssd_dram_ns
    t += lat
    _record(m.stats, "hit_cache", lat)
    m.stats.replays += 1
    return th.i + 1, t


def _run_span(m: Machine, cfg: SimConfig, th: Thread, t: float, wslots,
              i: int, stop: int) -> Tuple[int, float, bool]:
    """Exact per-event replay of th's trace events [i, stop).

    Returns (next_i, t, blocked). On a coordinated context switch the
    blocked access is NOT consumed (it is replayed after wakeup)."""
    page_a, line_a, write_a, gap_a = th.page, th.line, th.write, th.gap64
    serve = m.serve
    st = m.stats
    while i < stop:
        t += gap_a[i]
        lat, blocked_until, cls = serve(int(page_a[i]), int(line_a[i]),
                                        bool(write_a[i]), t, wslots)
        if blocked_until is not None:
            th.ready = blocked_until
            th.replay = True
            t += cfg.ctx_switch_ns  # core-side switch cost
            return i, t, True
        t += lat
        _record(st, cls, lat)
        i += 1
    return i, t, False


def _reference_quantum(m: Machine, cfg: SimConfig, th: Thread, t: float,
                       wslots) -> float:
    """Run one scheduling quantum with the per-event reference engine."""
    i = th.i
    if th.replay:  # replayed access after a context switch (§III-A 4)
        i, t = _replay_prologue(m, cfg, th, t)
    i, t, _ = _run_span(m, cfg, th, t, wslots, i, th.n)
    th.i = i
    return t


def _run_scheduler(m: Machine, cfg: SimConfig, threads: List[Thread],
                   runner) -> List[float]:
    """Scheduler driver: dense per-thread state + two priority queues.

    Per-thread wake time / CFS vruntime / RR last-sched stamp live in
    dense lists; selection runs on two small heaps instead of a per-
    quantum scan over thread objects: a *wake queue* ordered by wake
    time and a *run queue* ordered by (policy key, thread index). Every
    non-done thread sits in exactly one queue, keys only change while a
    thread is OUT of its queue (vruntime/last_sched change when it runs,
    wake time when it parks), so entries are never stale. The (key, tid)
    tuple ordering reproduces the historical candidate scan exactly:
    same wake condition (ready <= t_now), same first-minimal-thread-
    index tie-break. RANDOM keeps an index-ordered runnable list so its
    rng.choice stream is unchanged.

    KEEP IN SYNC with engine.run_fused, which reproduces this selection
    logic verbatim (same wake condition, same tie-breaks, same RANDOM rng
    stream) with the boundary-dense span kernel fused into the loop.
    Returns the per-core clock list."""
    n_cores = cfg.n_cores
    cores = [0.0] * n_cores
    wslots_per_core: List[List[float]] = [[] for _ in range(n_cores)]
    sched_counter = 0
    nt = len(threads)
    n_alive = nt
    vrun = [0.0] * nt
    last_sched = [0] * nt
    use_cfs = cfg.sched_policy == "CFS"
    use_random = cfg.sched_policy == "RANDOM"
    heappush, heappop = heapq.heappush, heapq.heappop
    wake_q: List[Tuple[float, int]] = []
    if use_random:
        run_l = list(range(nt))  # all runnable at t=0, thread-index order
        rng_choice = m.rng.choice
    else:
        keys = vrun if use_cfs else last_sched
        run_q = [(0, ti) for ti in range(nt)]  # all runnable, key 0

    while n_alive:
        # core with the earliest time (first minimal index, like
        # min(range, key))
        t_now = min(cores)
        c = cores.index(t_now)
        if use_random:
            while wake_q and wake_q[0][0] <= t_now:
                bisect.insort(run_l, heappop(wake_q)[1])
            if not run_l:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = rng_choice(run_l)
            run_l.remove(ti)
        else:
            while wake_q and wake_q[0][0] <= t_now:
                ti = heappop(wake_q)[1]
                heappush(run_q, (keys[ti], ti))
            if not run_q:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = heappop(run_q)[1]
        sched_counter += 1
        last_sched[ti] = sched_counter
        th = threads[ti]
        r = th.ready
        t = t_now if t_now >= r else r
        t0 = t
        t = runner(m, cfg, th, t, wslots_per_core[c])
        vrun[ti] += t - t0
        if th.i >= th.n and not th.replay:
            th.done = True
            n_alive -= 1
        else:
            heappush(wake_q, (th.ready, ti))
        cores[c] = t
    return cores


def _advance_idle_cores(cores: List[float], t_now: float, wake: float) -> None:
    """No thread is runnable at t_now: jump every core sitting before the
    next wake time straight to it. Equivalent to the historical
    one-core-per-iteration advance (each idle core would advance to the
    same wake on its own turn, in index order, with no state change in
    between) but without re-running candidate selection per core."""
    if wake <= t_now:  # defensive: a candidate would exist (ready <= t_now)
        wake = t_now + 1.0
    for ci in range(len(cores)):
        if cores[ci] < wake:
            cores[ci] = wake


def simulate(
    workload: str,
    variant: str,
    cfg: SimConfig = SimConfig(),
    total_req: int = 400_000,
    seed: int = 0,
    n_threads: int = 0,
) -> Dict[str, Any]:
    """Run one (workload, variant) experiment; returns a stats dict.

    ``total_req`` is the total work of the program, split evenly across the
    variant's thread count (the paper runs the same program with 8 or 24
    threads; more threads never means more work). ``n_threads`` overrides
    the variant default (thread-scaling studies, Fig 15/22).

    ``cfg.engine`` selects the replay engine: "batched" (default) uses the
    vectorized fast path in engine.py; "reference" forces the original
    per-event loop. Both engines operate on the same DeviceState class and
    produce identical statistics for the same seed.
    """
    cfg = cfg.variant(variant)
    if n_threads:
        cfg = dataclasses.replace(cfg, n_threads=n_threads)
    env_engine = os.environ.get("REPRO_SIM_ENGINE")
    if env_engine:
        cfg = dataclasses.replace(cfg, engine=env_engine)
    if cfg.engine not in ENGINES:
        raise ValueError(f"unknown SimConfig.engine: {cfg.engine!r}; "
                         f"valid engines: {', '.join(ENGINES)}")
    n_req = max(total_req // cfg.n_threads, 1)
    traces = gen_traces(workload, cfg.n_threads, n_req, seed=seed, scale=cfg.scale)
    threads = [Thread(t, tr) for t, tr in enumerate(traces)]
    page_space = int(max(tr["n_pages"] for tr in traces))

    use_turbo = cfg.engine == "turbo"
    use_batched = cfg.engine == "batched" or use_turbo
    if use_batched:
        from repro.core import engine as _engine

        use_batched = _engine.supported(cfg)
        use_turbo = use_turbo and use_batched
    _turbo = None
    if use_turbo:
        from repro.core import turbo as _turbo_mod

        _turbo = _turbo_mod
        _engine.reset_cache_stats()
        _engine.reset_fused_stats()
        _turbo.reset_turbo_stats()
        m = _engine.BatchedMachine(cfg, seed, page_space)
        # fast-math driver: run_fused's structure with the float timeline
        # chains replaced by gap prefix-sums + count*constant folds
        cores = _turbo.run_turbo(m, cfg, threads)
    elif use_batched:
        _engine.reset_cache_stats()
        _engine.reset_fused_stats()
        m = _engine.BatchedMachine(cfg, seed, page_space)
        # fused cross-thread driver: scheduler + span kernel in one loop
        # (same selection semantics as _run_scheduler)
        cores = _engine.run_fused(m, cfg, threads)
    else:
        m = Machine(cfg, seed, page_space)
        cores = _run_scheduler(m, cfg, threads, _reference_quantum)

    st = m.stats
    ds = m.state
    if _turbo is not None:
        # the engine's own reassociation bound over the run's timelines
        # (0.0 when the conflict-class fallback ran the exact path)
        st.turbo_drift_max = _turbo.TURBO_STATS["drift_bound_max"]
        st.turbo_drift_mean = _turbo.TURBO_STATS["drift_bound_mean"]
    exec_ns = max(cores)
    st.exec_ns = exec_ns
    st.busy_ns = ds.chan_busy_ns
    st.gc_events = ds.gc_events
    st.finalize(cfg, ds)
    out = st.as_dict()
    if m.obs is not None:  # latency-provenance summary (core/obs.py)
        out["obs"] = m.obs.finalize(st, ds)
    if ds.flash is not None:  # block FTL wear accounting
        out["wear_max_erases"] = int(ds.flash.blk_erase.max())
        out["wear_mean_erases"] = float(ds.flash.blk_erase.mean())
    out.update(
        workload=workload, variant=variant, n_threads=cfg.n_threads,
        n_req_per_thread=n_req,
        total_req=st.n,
        throughput_rps=st.n / max(exec_ns, 1e-9) * 1e9,
        ssd_bw_util=ds.chan_busy_ns / max(exec_ns * cfg.n_channels, 1e-9),
        flash_reads=ds.flash_reads, flash_writes=ds.flash_writes,
        compactions=(ds.log_compactions if m.log else 0),
        coalesce_ratio=(
            ds.log_flushed_lines * LINE / max(ds.log_flushed_pages * PAGE, 1)
            if m.log else None
        ),
    )
    return out
