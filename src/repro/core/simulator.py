"""SkyByte system simulator — multi-core trace replay against the CXL-SSD.

Reproduces the paper's evaluation harness (§V): per-thread off-chip access
traces are replayed on N cores against the device model in ssd.py, with the
three SkyByte mechanisms as selectable flags (SimConfig.variant), exactly
mirroring the §VI-A ablation grid:

  Base-CSSD    — page-granular DRAM cache only (write-allocate, write-back)
  SkyByte-C    — + coordinated context switch (Algorithm 1 trigger)
  SkyByte-P    — + adaptive page promotion to host DRAM
  SkyByte-W    — + cacheline write log & compaction
  -CP/-WP/Full — combinations
  DRAM-Only    — ideal infinite host DRAM

Timing model (request-event level; deltas vs the paper's cycle-accurate
MacSim are confined to sub-100ns effects and documented in DESIGN.md):
  host DRAM hit   : host_dram_ns
  SSD log hit     : cxl + log_index + ssd_dram
  SSD cache hit   : cxl + cache_index + ssd_dram
  SSD miss        : cxl + cache_index + channel queue + t_read + ssd_dram
  context switch  : ctx_switch_ns charged to the core; blocked thread
                    becomes runnable at flash completion; the re-issued
                    (replayed) access is charged as an SSD DRAM hit, and
                    the squashed original is excluded from AMAT (§VI-D).

Scheduling policies: RR / RANDOM / CFS (default, vruntime-based).

Two replay engines share the scheduler (SimConfig.engine):
  "reference" — the original pure-Python per-event loop (ground truth);
  "batched"   — the vectorized fast path in engine.py, which resolves runs
                of state-stable accesses with NumPy bulk passes and drops
                to the exact per-event path at state-changing boundaries.
Both produce identical Stats (see tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import os
import random
from collections import OrderedDict
from operator import attrgetter
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.configs.base import SimConfig
from repro.core.ssd import Channels, DataCache, Ftl, WriteLog
from repro.core.traces import gen_traces

PAGE = 4096
LINE = 64


class Stats:
    __slots__ = (
        "n", "host_r", "host_w", "hit_log", "hit_cache", "miss_flash", "ssd_w",
        "lat_sum", "lat_host", "lat_hit", "lat_miss", "ctx_switches",
        "flash_write_pages", "gc_events", "promotions", "demotions",
        "exec_ns", "busy_ns", "replays",
    )

    def __init__(self):
        for f in self.__slots__:
            setattr(self, f, 0)

    def as_dict(self) -> Dict[str, Any]:
        d = {f: getattr(self, f) for f in self.__slots__}
        n = max(self.n, 1)
        d["amat_ns"] = self.lat_sum / n
        d["flash_write_bytes"] = self.flash_write_pages * PAGE
        return d


class Thread:
    __slots__ = ("tid", "page", "line", "write", "gap64", "i", "n",
                 "ready", "vruntime", "last_sched", "running", "replay", "done")

    def __init__(self, tid: int, trace: Dict):
        self.tid = tid
        self.page = trace["page"]
        self.line = trace["line"]
        self.write = trace["write"]
        # Traces carry float32 gaps; accumulate core time in float64 — a
        # float32 timeline loses whole-ns resolution past ~16ms of sim time
        # (1 ulp at 1e9 ns is 64 ns, bigger than every SSD-DRAM latency).
        self.gap64 = np.asarray(trace["gap_ns"], dtype=np.float64)
        self.i = 0
        self.n = len(self.page)
        self.ready = 0.0
        self.vruntime = 0.0
        self.last_sched = 0
        self.running = False
        self.replay = False
        self.done = False


class Machine:
    def __init__(self, cfg: SimConfig, seed: int = 0):
        self.cfg = cfg
        self.channels = Channels(cfg)
        self.ftl = Ftl(cfg, self.channels)
        self.cache = DataCache(cfg)
        self.log = WriteLog(cfg) if cfg.enable_write_log else None
        self.host: "OrderedDict[int, bool]" = OrderedDict()
        self.host_cap = max(cfg.host_pages, 1)
        self.acc_count: Dict[int, int] = {}
        self.stats = Stats()
        self.rng = random.Random(seed)

    # ---- promotion (§III-C; §VI-H alternative policies) ----
    def _maybe_promote(self, page: int, now: float) -> None:
        cfg = self.cfg
        if not cfg.enable_promotion:
            return
        if cfg.promo_policy == "tpp":
            # TPP: periodic sampling — hotness observed only 1/4 of the time
            if self.rng.random() < 0.75:
                return
            c = self.acc_count.get(page, 0) + 1
            self.acc_count[page] = c
            if c < max(cfg.promote_threshold // 4, 2) or page in self.host:
                return
        elif cfg.promo_policy == "astriflash":
            # AstriFlash: host DRAM as a page cache of the SSD — every
            # touched page is installed (no hotness filter)
            if page in self.host:
                return
        else:
            c = self.acc_count.get(page, 0) + 1
            self.acc_count[page] = c
            if c < cfg.promote_threshold or page in self.host:
                return
        # paper: only pages resident in SSD DRAM cache are candidates
        if self.cache.lookup(page, touch=False) is None:
            return
        if len(self.host) >= self.host_cap:
            # Linux-reclaim-style: demote the coldest (LRU order) page
            cold, _ = self.host.popitem(last=False)
            self.stats.demotions += 1
            self.acc_count[cold] = 0  # restart hotness tracking (no ping-pong)
            ev = self.cache.insert(cold, True)  # back to SSD DRAM, dirty
            self._handle_evict(ev, now)
        self.host[page] = True
        self.cache.remove(page)
        self.stats.promotions += 1

    def _handle_evict(self, ev, now: float) -> None:
        if ev is not None and ev[1]:  # dirty page writeback
            self.channels.write(ev[0], now)
            self.ftl.on_flash_write(now)
            self.stats.flash_write_pages += 1

    # ---- compaction (§III-B) ----
    def _compact(self, now: float) -> None:
        """Background log compaction. Flushes are *staggered* so compaction
        uses at most ~half of each channel's bandwidth — the paper drains
        the old log off the critical path (146 us per compaction step,
        §III-B) rather than monopolizing the flash channels; foreground
        reads must keep making progress between compaction programs."""
        log = self.log
        old = log.swap_for_compaction()
        for page, lines in old.items():
            if self.cache.lookup(page, touch=False) is None:
                self.channels.read(page, now)  # coalescing-buffer fill
            self.channels.write(page, now)
            self.ftl.on_flash_write(now)
            self.stats.flash_write_pages += 1
            log.flushed_pages += 1
            log.flushed_lines += len(lines)
        log.finish_compaction()

    # ---- request service ----
    def serve(self, page: int, line: int, is_write: bool, now: float, wslots):
        """Returns (latency_ns, blocked_until or None, amat_class).

        blocked_until is set when the coordinated context switch fires:
        the thread parks until flash completion and replays the access.
        ``wslots``: per-core in-flight posted-write completion times (models
        the MSHR/write-buffer bound max_outstanding — a Base-CSSD write miss
        fetches its page in the background and only stalls the core when all
        slots are occupied).
        """
        cfg = self.cfg
        st = self.stats
        if cfg.dram_only:
            cls = "host_w" if is_write else "host_r"
            return cfg.host_dram_ns, None, cls

        if page in self.host:
            self.host.move_to_end(page)
            return cfg.host_dram_ns, None, ("host_w" if is_write else "host_r")

        base = cfg.cxl_protocol_ns
        if is_write:
            if self.log is not None:
                lat = base + cfg.log_index_ns + cfg.ssd_dram_ns
                full = self.log.append(page, line)
                if self.cache.lookup(page, touch=False) is not None:
                    pass  # parallel in-place cache update (kept consistent)
                if full:
                    self._compact(now)
                self._maybe_promote(page, now)
                return lat, None, "ssd_w"
            # Base-CSSD: write-allocate into the page cache (posted store;
            # background page fetch occupies a write slot)
            hit = self.cache.lookup(page)
            if hit is not None:
                self.cache.mark_dirty(page)
                self._maybe_promote(page, now)
                return base + cfg.cache_index_ns + cfg.ssd_dram_ns, None, "ssd_w"
            stall = 0.0
            if len(wslots) >= cfg.max_outstanding:
                oldest = min(wslots)
                wslots.remove(oldest)
                stall = max(0.0, oldest - now)
            done = self.channels.read(page, now + stall)
            wslots.append(done)
            ev = self.cache.insert(page, True)
            self._handle_evict(ev, now)
            self._maybe_promote(page, now)
            lat = stall + base + cfg.cache_index_ns + cfg.ssd_dram_ns
            return lat, None, "ssd_w"

        # ---- read ----
        if self.log is not None and self.log.lookup(page, line):
            self._maybe_promote(page, now)
            return base + cfg.log_index_ns + cfg.ssd_dram_ns, None, "hit_log"
        if self.cache.lookup(page) is not None:
            self._maybe_promote(page, now)
            return base + cfg.cache_index_ns + cfg.ssd_dram_ns, None, "hit_cache"
        # SSD DRAM miss -> flash
        if cfg.enable_ctx_switch:
            est = self.channels.estimate(page, now)
            if est > cfg.ctx_threshold_ns:
                done = self.channels.read(page, now)
                ev = self.cache.insert(page, False if self.log is not None else False)
                self._handle_evict(ev, now)
                st.ctx_switches += 1
                self._maybe_promote(page, now)
                return 0.0, done, "switched"
        done = self.channels.read(page, now)
        ev = self.cache.insert(page, False)
        self._handle_evict(ev, now)
        self._maybe_promote(page, now)
        lat = (done - now) + base + cfg.cache_index_ns + cfg.ssd_dram_ns
        return lat, None, "miss_flash"


_CLS_LAT = ("host_r", "host_w", "hit_log", "hit_cache", "miss_flash", "ssd_w")
# C-level min() keys for the scheduler (same first-minimum tie-break as the
# equivalent lambdas, ~3x cheaper per candidate scan)
_BY_VRUNTIME = attrgetter("vruntime")
_BY_LAST_SCHED = attrgetter("last_sched")


def _record(st: Stats, cls: str, lat: float) -> None:
    """Charge one retired request to the Stats counters."""
    st.n += 1
    st.lat_sum += lat
    if cls == "host_r":
        st.host_r += 1
        st.lat_host += lat
    elif cls == "host_w":
        st.host_w += 1
        st.lat_host += lat
    elif cls == "hit_log":
        st.hit_log += 1
        st.lat_hit += lat
    elif cls == "hit_cache":
        st.hit_cache += 1
        st.lat_hit += lat
    elif cls == "ssd_w":
        st.ssd_w += 1
        st.lat_hit += lat
    else:
        st.miss_flash += 1
        st.lat_miss += lat


def _replay_prologue(m: Machine, cfg: SimConfig, th: Thread, t: float):
    """Re-issue of a context-switched access (§III-A 4): charged as an SSD
    DRAM hit; the squashed original was excluded from AMAT. Returns the new
    (i, t) after consuming the replayed access."""
    th.replay = False
    lat = cfg.cxl_protocol_ns + cfg.cache_index_ns + cfg.ssd_dram_ns
    t += lat
    _record(m.stats, "hit_cache", lat)
    m.stats.replays += 1
    return th.i + 1, t


def _run_span(m: Machine, cfg: SimConfig, th: Thread, t: float, wslots,
              i: int, stop: int) -> Tuple[int, float, bool]:
    """Exact per-event replay of th's trace events [i, stop).

    Returns (next_i, t, blocked). On a coordinated context switch the
    blocked access is NOT consumed (it is replayed after wakeup)."""
    page_a, line_a, write_a, gap_a = th.page, th.line, th.write, th.gap64
    serve = m.serve
    st = m.stats
    while i < stop:
        t += gap_a[i]
        lat, blocked_until, cls = serve(int(page_a[i]), int(line_a[i]),
                                        bool(write_a[i]), t, wslots)
        if blocked_until is not None:
            th.ready = blocked_until
            th.replay = True
            t += cfg.ctx_switch_ns  # core-side switch cost
            return i, t, True
        t += lat
        _record(st, cls, lat)
        i += 1
    return i, t, False


def _reference_quantum(m: Machine, cfg: SimConfig, th: Thread, t: float,
                       wslots) -> float:
    """Run one scheduling quantum with the per-event reference engine."""
    i = th.i
    if th.replay:  # replayed access after a context switch (§III-A 4)
        i, t = _replay_prologue(m, cfg, th, t)
    i, t, _ = _run_span(m, cfg, th, t, wslots, i, th.n)
    th.i = i
    return t


def simulate(
    workload: str,
    variant: str,
    cfg: SimConfig = SimConfig(),
    total_req: int = 400_000,
    seed: int = 0,
    n_threads: int = 0,
) -> Dict[str, Any]:
    """Run one (workload, variant) experiment; returns a stats dict.

    ``total_req`` is the total work of the program, split evenly across the
    variant's thread count (the paper runs the same program with 8 or 24
    threads; more threads never means more work). ``n_threads`` overrides
    the variant default (thread-scaling studies, Fig 15/22).

    ``cfg.engine`` selects the replay engine: "batched" (default) uses the
    vectorized fast path in engine.py and falls back to the reference loop
    for configurations it does not support (stochastic promotion policies);
    "reference" forces the original per-event loop. Both engines produce
    identical statistics for the same seed.
    """
    cfg = cfg.variant(variant)
    if n_threads:
        cfg = dataclasses.replace(cfg, n_threads=n_threads)
    env_engine = os.environ.get("REPRO_SIM_ENGINE")
    if env_engine:
        cfg = dataclasses.replace(cfg, engine=env_engine)
    if cfg.engine not in ("reference", "batched"):
        raise ValueError(f"unknown SimConfig.engine: {cfg.engine!r}")
    n_req = max(total_req // cfg.n_threads, 1)
    traces = gen_traces(workload, cfg.n_threads, n_req, seed=seed, scale=cfg.scale)
    threads = [Thread(t, tr) for t, tr in enumerate(traces)]

    use_batched = cfg.engine == "batched"
    if use_batched:
        from repro.core import engine as _engine

        use_batched = _engine.supported(cfg)
    if use_batched:
        page_space = int(max(tr["n_pages"] for tr in traces))
        _engine.reset_cache_stats()
        m = _engine.BatchedMachine(cfg, seed, page_space)
        runner = _engine.batched_quantum
    else:
        m = Machine(cfg, seed)
        runner = _reference_quantum

    st = m.stats
    n_cores = cfg.n_cores
    cores = [0.0] * n_cores
    wslots_per_core: List[List[float]] = [[] for _ in range(n_cores)]
    policy = cfg.sched_policy
    sched_counter = 0
    # alive keeps thread-index order, so candidate lists (and their
    # tie-breaks) match a scan over the full thread table
    alive = list(threads)

    while alive:
        # core with the earliest time (first minimal index, like
        # min(range, key))
        t_now = min(cores)
        c = cores.index(t_now)
        cand = [th for th in alive if not th.running and th.ready <= t_now]
        if not cand:
            waits = [th.ready for th in alive if not th.running]
            if not waits:  # all pending threads running on other cores
                cores[c] = min(x for x in cores if x > t_now) if any(
                    x > t_now for x in cores) else t_now + 1.0
                continue
            cores[c] = max(t_now, min(waits))
            continue
        if policy == "CFS":
            th = min(cand, key=_BY_VRUNTIME)
        elif policy == "RANDOM":
            th = m.rng.choice(cand)
        else:  # RR
            th = min(cand, key=_BY_LAST_SCHED)
        sched_counter += 1
        th.last_sched = sched_counter
        th.running = True
        t = max(t_now, th.ready)
        t0 = t
        t = runner(m, cfg, th, t, wslots_per_core[c])
        th.vruntime += t - t0
        th.running = False
        if th.i >= th.n and not th.replay:
            th.done = True
            alive.remove(th)
        cores[c] = t

    exec_ns = max(cores)
    st.exec_ns = exec_ns
    st.busy_ns = m.channels.busy_ns
    st.gc_events = m.channels.gc_events
    out = st.as_dict()
    out.update(
        workload=workload, variant=variant, n_threads=cfg.n_threads,
        n_req_per_thread=n_req,
        total_req=st.n,
        throughput_rps=st.n / max(exec_ns, 1e-9) * 1e9,
        ssd_bw_util=m.channels.busy_ns / max(exec_ns * cfg.n_channels, 1e-9),
        flash_reads=m.channels.reads, flash_writes=m.channels.writes,
        compactions=(m.log.compactions if m.log else 0),
        coalesce_ratio=(
            m.log.flushed_lines * LINE / max(m.log.flushed_pages * PAGE, 1)
            if m.log else None
        ),
    )
    return out
