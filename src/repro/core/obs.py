"""Latency provenance — additive per-request breakdown, interval
timelines, and a bounded device-event recorder (``SimConfig.obs``).

Opt-in observability layer over the replay engines. When attached
(``cfg.obs.enabled``) every host-visible completion is decomposed into
additive components — CXL port transit, die queue wait, channel-bus
transfer wait, flash sense, GC pause (carved window vs suspend/resume
penalty), fault retry-ladder time, recovery barrier, outage wait,
index/DRAM constants — under a *conservation contract*: the components
of each request sum bit-exactly (left-to-right IEEE-754 addition) to
the latency the engine recorded for that request.

Exactness scheme. Timestamps are arbitrary doubles (float32 trace gaps
accumulated in float64), so a naive decomposition into independently
rounded timestamp differences misses the recorded latency by ulps:
``fl(a + fl(b - a)) == b`` holds only under Sterbenz conditions. Every
request chain therefore keeps one *closure slot* — the die-queue wait,
the only component that is itself defined as a residual — and a
verify-and-nudge loop adds the rounding residue (lat - chain_sum) into
it until the left-to-right chain sum reproduces the recorded latency
bit-exactly (<= 2 iterations in practice). A guaranteed-terminating
fallback collapses the whole chain into the closure slot (x + 0.0 == x
makes that sum exact by construction) and is counted in
``closure_fallbacks``; a ``violations`` counter records any request
whose final chain still missed — structurally impossible, asserted
zero in tests/test_obs.py.

Conflict-class contract (KEEP IN SYNC with qos.py / faults.py and the
engine's mirrored sites): obs-active cells refuse ``run_fused`` and run
through ``batched_quantum`` / the reference loop. Every gc-attributed
flash read *stages* its device-side components inside the ONE read
dispatch both engines share (``Channels.read`` or the attached
``QosModel.read`` / ``FaultModel.read``); the engines add only
commit/park calls at their existing retire sites. Both engines retire
the same requests in the same global order, so every obs artifact
(per-event chains, totals, interval windows, events, slowest-K) is
bit-identical across engines. Zero-obs configs construct nothing and
pay one ``is not None`` test per site.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Dict, List

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL
from repro.core.simulator import (_LAT_NBINS, _lat_bin, _lat_bin_edge,
                                  percentiles_from_items)

# Flash-read chain slot order. The closure slot (queue) comes first; the
# three constant tail slots close the chain on the engine's recorded
# latency, so a read miss decomposes without referencing the engine's
# own expression shape.
_CHAIN = ("queue", "gc_pause", "gc_suspend", "recovery", "outage",
          "sense", "retry", "bus_wait", "transfer")
_NCH = len(_CHAIN)
_RCHAIN = _CHAIN + ("cxl", "cache_index", "ssd_dram")
_NR = len(_RCHAIN)
# Write-slot-stall chain (Base-CSSD posted-write backpressure); wstall
# is the closure slot.
_WCHAIN = ("wstall", "cxl", "cache_index", "ssd_dram")

# Perfetto synthetic track ids (see to_perfetto docstring)
_PID_DEVICE = 999     # device-global: recovery barriers, compactions
_PID_SLOW = 1000      # slowest-K request slices
_TID_BUS = 998        # per-channel bus track (transfer convoys)


class ObsModel:
    """Per-run latency-provenance recorder; one per Machine when
    ``cfg.obs.enabled`` (see the module docstring for the contract)."""

    __slots__ = (
        "cfg", "knobs",
        # config constants (locals of every commit)
        "cxl", "cache_ix", "log_ix", "dram", "host_dram", "w_index_log",
        # recovery-barrier horizon (set by FaultModel._power_loss)
        "rec_until",
        # staged flash read awaiting its engine retire site
        "s_ch", "s_d", "s_now", "s_done", "s_parts",
        # per-component accounting
        "tot", "hist", "hist_w", "n_miss", "n_stall",
        "checked", "violations", "closure_fallbacks", "gc_pause_site",
        # interval ring
        "window_ns", "folds", "max_idx",
        "win_reads", "win_stall", "win_programs", "win_gc_migrated",
        "win_gc_pause", "win_gc_busy", "win_qmax",
        "win_miss_h", "win_stall_h",
        # event recorder + slowest-K heap
        "events", "ev_emitted", "slow", "slow_seq",
    )

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        oc = cfg.obs
        self.knobs = oc
        self.cxl = cfg.cxl_protocol_ns
        self.cache_ix = cfg.cache_index_ns
        self.log_ix = cfg.log_index_ns
        self.dram = cfg.ssd_dram_ns
        self.host_dram = cfg.host_dram_ns
        self.w_index_log = cfg.enable_write_log  # const-write index class
        self.rec_until = 0.0
        self.s_ch = 0
        self.s_d = 0
        self.s_now = 0.0
        self.s_done = 0.0
        self.s_parts = [0.0] * _NR
        self.tot = {name: 0.0 for name in _RCHAIN}
        for name in ("wstall", "log_index", "host_dram"):
            self.tot[name] = 0.0
        self.hist = {name: np.zeros(_LAT_NBINS, np.int64) for name in _CHAIN}
        self.hist_w = np.zeros(_LAT_NBINS, np.int64)
        self.n_miss = 0
        self.n_stall = 0
        self.checked = 0
        self.violations = 0
        self.closure_fallbacks = 0
        self.gc_pause_site = 0.0
        mw = oc.max_windows
        self.window_ns = oc.window_ns
        self.folds = 0
        self.max_idx = -1
        self.win_reads = np.zeros(mw, np.int64)
        self.win_stall = np.zeros(mw, np.int64)
        self.win_programs = np.zeros(mw, np.int64)
        self.win_gc_migrated = np.zeros(mw, np.int64)
        self.win_gc_pause = np.zeros(mw, np.float64)
        self.win_gc_busy = np.zeros(mw, np.float64)
        self.win_qmax = np.zeros(mw, np.float64)
        self.win_miss_h = np.zeros((mw, _LAT_NBINS), np.int64)
        self.win_stall_h = np.zeros((mw, _LAT_NBINS), np.int64)
        self.events: deque = deque(maxlen=oc.max_events)
        self.ev_emitted = 0
        self.slow: List[tuple] = []
        self.slow_seq = 0

    # ---- interval ring -------------------------------------------------
    def _widx(self, now: float) -> int:
        """Window index of ``now``; folds the ring on overflow."""
        i = int(now // self.window_ns)
        while i >= len(self.win_reads):
            self._fold()
            i = int(now // self.window_ns)
        if i > self.max_idx:
            self.max_idx = i
        return i

    def _fold(self) -> None:
        """Pairwise-fold the ring into half the windows at double the
        width. Pure sums/maxes over fixed pairs — the folded state is
        independent of arrival order within a window, and the fold
        *trigger* depends only on the event sequence, which is identical
        across engines, so interval parity stays structural."""
        mw = len(self.win_reads)
        h = mw // 2
        for a in (self.win_reads, self.win_stall, self.win_programs,
                  self.win_gc_migrated, self.win_gc_pause, self.win_gc_busy):
            a[:h] = a[0::2] + a[1::2]
            a[h:] = 0
        q = self.win_qmax
        q[:h] = np.maximum(q[0::2], q[1::2])
        q[h:] = 0.0
        for hh in (self.win_miss_h, self.win_stall_h):
            hh[:h] = hh[0::2] + hh[1::2]
            hh[h:] = 0
        self.window_ns *= 2.0
        self.folds += 1
        self.max_idx //= 2

    # ---- device-side capture -------------------------------------------
    def stage_read(self, ch: int, d: int, now: float, die_wait: float,
                   queue: float, gc_pause: float, gc_suspend: float,
                   recovery: float, outage: float, sense: float,
                   retry: float, bus_wait: float, transfer: float,
                   done: float) -> None:
        """Record a gc-attributed flash read's device-side component
        estimates (called from the one read dispatch both engines
        share). The engine's retire site either commits the stage
        (``commit_read_miss``) or drops it (``on_park``). The split
        estimates need not be exact — the closure slot absorbs rounding
        at commit — but each is the very float the device model
        computed, so e.g. the gc_pause slot matches the pause booked
        into ``gc_pause_ns_total`` bit-exactly."""
        self.s_ch = ch
        self.s_d = d
        self.s_now = now
        self.s_done = done
        p = self.s_parts
        p[0] = queue
        p[1] = gc_pause
        p[2] = gc_suspend
        p[3] = recovery
        p[4] = outage
        p[5] = sense
        p[6] = retry
        p[7] = bus_wait
        p[8] = transfer
        p[9] = self.cxl
        p[10] = self.cache_ix
        p[11] = self.dram
        i = self._widx(now)
        self.win_reads[i] += 1
        if die_wait > self.win_qmax[i]:
            self.win_qmax[i] = die_wait
        self.win_gc_pause[i] += gc_pause + gc_suspend
        if bus_wait > self.knobs.convoy_ns:
            t1 = done - transfer
            self._emit({"kind": "convoy", "ch": ch, "d": d,
                        "t0_ns": t1 - bus_wait, "t1_ns": t1})

    def mirror_gc_pause(self, pause: float) -> None:
        """Bit-exact mirror of the device's ``gc_pause_ns_total``
        accumulation: called adjacent to every booking site with the
        same float, in the same order — ``gc_pause_site`` must end equal
        (==, not isclose) to ``ds.gc_pause_ns_total``."""
        self.gc_pause_site += pause

    # ---- engine retire sites -------------------------------------------
    def _close(self, p: List[float], lat: float, n: int) -> None:
        """Nudge the closure slot p[0] until the left-to-right sum of
        p[:n] reproduces ``lat`` bit-exactly; collapse on the (counted)
        pathological miss."""
        ok = False
        for _ in range(5):
            s = 0.0
            for k in range(n):
                s = s + p[k]
            if s == lat:
                ok = True
                break
            p[0] += lat - s
        if not ok:
            for k in range(n):
                p[k] = 0.0
            p[0] = lat
            self.closure_fallbacks += 1
        self.checked += 1
        s = 0.0
        for k in range(n):  # defensive re-verify; structurally always ==
            s = s + p[k]
        if s != lat:
            self.violations += 1

    def commit_read_miss(self, lat: float) -> None:
        """Retire the staged flash read against the engine's recorded
        miss latency (KEEP IN SYNC: serve(), _inline_span and
        batched_quantum call this at their read-miss retire sites)."""
        p = self.s_parts
        self._close(p, lat, _NR)
        tot = self.tot
        hist = self.hist
        for k in range(_NCH):
            v = p[k]
            tot[_CHAIN[k]] += v
            hist[_CHAIN[k]][_lat_bin(v)] += 1
        tot["cxl"] += p[9]
        tot["cache_index"] += p[10]
        tot["ssd_dram"] += p[11]
        self.n_miss += 1
        self.win_miss_h[self._widx(self.s_now), _lat_bin(lat)] += 1
        k = self.knobs.slow_k
        if k > 0:
            self.slow_seq += 1
            rec = (lat, self.slow_seq, self.s_ch, self.s_d,
                   self.s_now, self.s_done, tuple(p))
            if len(self.slow) < k:
                heapq.heappush(self.slow, rec)
            elif rec > self.slow[0]:
                heapq.heapreplace(self.slow, rec)

    def commit_write_stall(self, lat: float, stall: float,
                           now: float) -> None:
        """Retire one MSHR-stalled posted write (Base-CSSD write miss
        with all slots occupied; the only variable-latency write)."""
        p = [stall, self.cxl, self.cache_ix, self.dram]
        self._close(p, lat, 4)
        tot = self.tot
        tot["wstall"] += p[0]
        tot["cxl"] += p[1]
        tot["cache_index"] += p[2]
        tot["ssd_dram"] += p[3]
        self.hist_w[_lat_bin(p[0])] += 1
        self.n_stall += 1
        i = self._widx(now)
        self.win_stall[i] += 1
        self.win_stall_h[i, _lat_bin(lat)] += 1

    def on_park(self) -> None:
        """Coordinated context switch fired: the blocked access is
        squashed (excluded from AMAT) and replayed later as a constant
        SSD-DRAM hit, so the staged read never retires. Device-side
        facts (interval reads, convoy events, the gc-pause mirror) were
        already booked at stage time and stand."""
        # nothing to drop explicitly: the next stage_read overwrites the
        # slots, and only commit_read_miss consumes them
        return

    # ---- device event hooks --------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        self.ev_emitted += 1
        self.events.append(ev)  # deque(maxlen) drops the oldest

    def on_gc_window(self, ch: int, d: int, t0: float, t1: float) -> None:
        """A new GC busy window carved on (ch, d) — every carve site in
        flash.py / ssd.py reports here (shared calls: both engines)."""
        self.win_gc_busy[self._widx(t0)] += t1 - t0
        self._emit({"kind": "gc_window", "ch": ch, "d": d,
                    "t0_ns": t0, "t1_ns": t1})

    def on_gc_busy(self, t0: float, dur: float) -> None:
        """GC die occupancy too fine-grained for the event ring (e.g.
        per-page stripe programs under superblock GC): interval
        accounting only, no event slice."""
        self.win_gc_busy[self._widx(t0)] += dur

    def on_gc_migrated(self, now: float, pages: int) -> None:
        self.win_gc_migrated[self._widx(now)] += pages

    def on_program(self, now: float) -> None:
        """One host/GC-independent flash program issued (window WAF)."""
        self.win_programs[self._widx(now)] += 1

    def on_suspend(self, ch: int, d: int, t0: float, t1: float) -> None:
        self._emit({"kind": "suspend", "ch": ch, "d": d,
                    "t0_ns": t0, "t1_ns": t1})

    def on_retry(self, ch: int, d: int, now: float, steps: int) -> None:
        self._emit({"kind": "retry", "ch": ch, "d": d,
                    "t0_ns": now, "steps": steps})

    def on_outage(self, ch: int, d: int, t0: float, t1: float) -> None:
        self._emit({"kind": "outage", "ch": ch, "d": d,
                    "t0_ns": t0, "t1_ns": t1})

    def on_recovery(self, t0: float, t1: float) -> None:
        """Power-loss recovery barrier: all timelines pushed to t1;
        subsequent die waits up to t1 are attributed to recovery."""
        self.rec_until = t1
        self._emit({"kind": "recovery", "t0_ns": t0, "t1_ns": t1})

    def on_compaction(self, now: float, pages: int) -> None:
        self._emit({"kind": "compaction", "t0_ns": now, "pages": pages})

    def on_die_fail(self, ch: int, d: int, now: float) -> None:
        self._emit({"kind": "die_fail", "ch": ch, "d": d, "t0_ns": now})

    # ---- summary --------------------------------------------------------
    def finalize(self, st, ds) -> Dict[str, Any]:
        """Fold the captured provenance into one JSON-safe summary block
        (exported as ``out["obs"]`` by simulate()).

        The per-event conservation contract is what is bit-exact; the
        component *totals* additionally fold in the constant-latency
        request classes (host hits, log/cache hits, constant posted
        writes) derived from the Stats counters as count x constant —
        no per-event hooks ever run on a hit path, so the vector fast
        path stays untouched."""
        cfg = self.cfg
        nm = self.n_miss
        ns = self.n_stall
        comps: Dict[str, Any] = {}
        for name in _CHAIN:
            h = self.hist[name]
            items = [(_lat_bin_edge(b), int(c))
                     for b, c in enumerate(h.tolist()) if c]
            p50, p95, p99 = percentiles_from_items(items, nm)
            comps[name] = {"total_ns": float(self.tot[name]), "n": nm,
                           "p50_ns": p50, "p95_ns": p95, "p99_ns": p99}
        items = [(_lat_bin_edge(b), int(c))
                 for b, c in enumerate(self.hist_w.tolist()) if c]
        p50, p95, p99 = percentiles_from_items(items, ns)
        comps["wstall"] = {"total_ns": float(self.tot["wstall"]), "n": ns,
                           "p50_ns": p50, "p95_ns": p95, "p99_ns": p99}
        # constant classes (derived; totals only — their percentile IS
        # the constant)
        w_const = st.ssd_w - st.ssd_w_var
        host = st.host_r + st.host_w
        w_ix = self.log_ix if self.w_index_log else self.cache_ix
        tot_cxl = self.tot["cxl"] \
            + self.cxl * (st.hit_log + st.hit_cache + w_const)
        tot_dram = self.tot["ssd_dram"] \
            + self.dram * (st.hit_log + st.hit_cache + w_const)
        tot_cix = self.tot["cache_index"] + self.cache_ix * st.hit_cache \
            + (0.0 if self.w_index_log else self.cache_ix * w_const)
        tot_lix = self.log_ix * st.hit_log \
            + (self.log_ix * w_const if self.w_index_log else 0.0)
        n_ssd = st.hit_log + st.hit_cache + w_const + ns + nm
        comps["cxl"] = {"total_ns": float(tot_cxl), "n": n_ssd,
                        "per_event_ns": self.cxl}
        comps["ssd_dram"] = {"total_ns": float(tot_dram), "n": n_ssd,
                             "per_event_ns": self.dram}
        comps["cache_index"] = {"total_ns": float(tot_cix),
                                "per_event_ns": self.cache_ix}
        comps["log_index"] = {"total_ns": float(tot_lix),
                              "per_event_ns": self.log_ix}
        comps["host_dram"] = {"total_ns": float(self.host_dram * host),
                              "n": host, "per_event_ns": self.host_dram}
        site = float(self.gc_pause_site)
        dev = float(ds.gc_pause_ns_total)
        conservation = {
            "checked": int(self.checked),
            "violations": int(self.violations),
            "closure_fallbacks": int(self.closure_fallbacks),
            "gc_pause_site_ns": site,
            "gc_pause_device_ns": dev,
            "gc_pause_exact": site == dev,
            "pass": self.violations == 0 and site == dev,
        }
        windows = []
        for i in range(self.max_idx + 1):
            mh = self.win_miss_h[i]
            tm = int(mh.sum())
            r99 = percentiles_from_items(
                [(_lat_bin_edge(b), int(c))
                 for b, c in enumerate(mh.tolist()) if c], tm, (0.99,))[0]
            sh = self.win_stall_h[i]
            tw = int(sh.sum())
            w99 = percentiles_from_items(
                [(_lat_bin_edge(b), int(c))
                 for b, c in enumerate(sh.tolist()) if c], tw, (0.99,))[0]
            prog = int(self.win_programs[i])
            mig = int(self.win_gc_migrated[i])
            windows.append({
                "t0_ns": i * self.window_ns,
                "reads": int(self.win_reads[i]), "misses": tm,
                "read_p99_ns": r99,
                "stalls": int(self.win_stall[i]), "write_p99_ns": w99,
                "gc_pause_ns": float(self.win_gc_pause[i]),
                "gc_busy_ns": float(self.win_gc_busy[i]),
                "gc_migrated": mig, "programs": prog,
                "waf": (prog + mig) / prog if prog else 1.0,
                "queue_max_ns": float(self.win_qmax[i]),
            })
        slowest = []
        for lat, seq, ch, d, t0, t1, parts in sorted(self.slow,
                                                     reverse=True):
            slowest.append({
                "lat_ns": float(lat), "seq": int(seq),
                "ch": int(ch), "d": int(d),
                "t0_ns": float(t0), "t1_ns": float(t1),
                "parts": {name: float(parts[k])
                          for k, name in enumerate(_RCHAIN)},
            })
        return {
            "meta": {
                "n_channels": cfg.n_channels,
                "dies_per_channel": DIES_PER_CHANNEL,
                "window_ns": self.window_ns,
                "folds": self.folds,
            },
            "n_miss": nm,
            "n_stall": ns,
            "components": comps,
            "conservation": conservation,
            "intervals": {
                "window_ns": self.window_ns,
                "folds": self.folds,
                "n_windows": self.max_idx + 1,
                "windows": windows,
            },
            "events": {
                "emitted": self.ev_emitted,
                "dropped": self.ev_emitted - len(self.events),
                "list": list(self.events),
            },
            "slowest": slowest,
        }


def to_perfetto(block: Dict[str, Any],
                title: str = "skybyte") -> Dict[str, Any]:
    """Convert one finalized obs summary block (simulate()'s
    ``out["obs"]``) into Chrome/Perfetto trace-event JSON — the dict
    serializes to a file https://ui.perfetto.dev loads directly.

    Track schema:
      pid = channel        one process per flash channel
        tid = die            X (complete) slices: carved GC windows,
                             suspends, outages; i (instant) marks:
                             fault retries, die failures
        tid = 998 ("bus")    X slices: channel-bus transfer convoys
      pid = 999 ("device")   device-global: power-loss recovery
                             barriers (X), log compactions (instant)
      pid = 1000 ("slowest") slowest-K requests, one X slice per rank,
                             tied to the serving die by an s/f flow
                             arrow; args carry the full component chain

    ``ts``/``dur`` are microseconds per the trace-event spec (the
    simulator's nanoseconds / 1e3); ``displayTimeUnit`` is "ns".
    """
    ev: List[Dict[str, Any]] = []
    meta = block.get("meta", {})
    nch = int(meta.get("n_channels", 0))
    used_pids = {}

    def _proc(pid: int, name: str) -> None:
        if pid not in used_pids:
            used_pids[pid] = True
            ev.append({"ph": "M", "pid": pid, "name": "process_name",
                       "args": {"name": name}})

    def _thread(pid: int, tid: int, name: str) -> None:
        key = (pid, tid)
        if key not in used_pids:
            used_pids[key] = True
            ev.append({"ph": "M", "pid": pid, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})

    for ch in range(nch):
        _proc(ch, f"channel {ch}")
    _proc(_PID_DEVICE, "device")
    _proc(_PID_SLOW, "slowest requests")

    for e in block.get("events", {}).get("list", []):
        kind = e["kind"]
        if kind in ("gc_window", "suspend", "outage"):
            ch, d = e["ch"], e["d"]
            _proc(ch, f"channel {ch}")
            _thread(ch, d, f"die {d}")
            ev.append({"ph": "X", "pid": ch, "tid": d, "name": kind,
                       "cat": "gc" if kind != "outage" else "fault",
                       "ts": e["t0_ns"] / 1e3,
                       "dur": max(e["t1_ns"] - e["t0_ns"], 0.0) / 1e3})
        elif kind == "convoy":
            ch = e["ch"]
            _proc(ch, f"channel {ch}")
            _thread(ch, _TID_BUS, "bus")
            ev.append({"ph": "X", "pid": ch, "tid": _TID_BUS,
                       "name": "convoy", "cat": "bus",
                       "ts": e["t0_ns"] / 1e3,
                       "dur": max(e["t1_ns"] - e["t0_ns"], 0.0) / 1e3,
                       "args": {"die": e["d"]}})
        elif kind == "recovery":
            ev.append({"ph": "X", "pid": _PID_DEVICE, "tid": 0,
                       "name": "recovery", "cat": "fault",
                       "ts": e["t0_ns"] / 1e3,
                       "dur": max(e["t1_ns"] - e["t0_ns"], 0.0) / 1e3})
        elif kind == "compaction":
            ev.append({"ph": "i", "pid": _PID_DEVICE, "tid": 1, "s": "p",
                       "name": "compaction", "cat": "log",
                       "ts": e["t0_ns"] / 1e3,
                       "args": {"pages": e["pages"]}})
        elif kind == "retry":
            ch, d = e["ch"], e["d"]
            _proc(ch, f"channel {ch}")
            _thread(ch, d, f"die {d}")
            ev.append({"ph": "i", "pid": ch, "tid": d, "s": "t",
                       "name": "retry", "cat": "fault",
                       "ts": e["t0_ns"] / 1e3,
                       "args": {"steps": e["steps"]}})
        elif kind == "die_fail":
            ch, d = e["ch"], e["d"]
            _proc(ch, f"channel {ch}")
            _thread(ch, d, f"die {d}")
            ev.append({"ph": "i", "pid": ch, "tid": d, "s": "g",
                       "name": "die_fail", "cat": "fault",
                       "ts": e["t0_ns"] / 1e3})

    _thread(_PID_DEVICE, 0, "recovery")
    _thread(_PID_DEVICE, 1, "compaction")
    for rank, r in enumerate(block.get("slowest", [])):
        ch, d = r["ch"], r["d"]
        _proc(ch, f"channel {ch}")
        _thread(ch, d, f"die {d}")
        _thread(_PID_SLOW, rank, f"#{rank}")
        t0us = r["t0_ns"] / 1e3
        ev.append({"ph": "X", "pid": _PID_SLOW, "tid": rank,
                   "name": f"slow#{rank} {r['lat_ns']:.0f}ns",
                   "cat": "slow", "ts": t0us,
                   "dur": max(r["t1_ns"] - r["t0_ns"], 0.0) / 1e3,
                   "args": dict(r["parts"])})
        fid = int(r["seq"])
        ev.append({"ph": "s", "pid": _PID_SLOW, "tid": rank,
                   "name": "served_by", "cat": "slow",
                   "id": fid, "ts": t0us})
        ev.append({"ph": "f", "bp": "e", "pid": ch, "tid": d,
                   "name": "served_by", "cat": "slow",
                   "id": fid, "ts": r["t1_ns"] / 1e3})
    return {"traceEvents": ev, "displayTimeUnit": "ns",
            "otherData": {"title": title}}
