"""Opt-in fast-math turbo engine — `SimConfig.engine="turbo"`.

The fused engine (engine.run_fused) hit the bit-exact CPython floor: four
sequential IEEE float chains (`t`, `lat_sum`, `lat_host`, `lat_hit`)
forbid reassociation, so every fast event pays ~4 scalar float adds even
though its latency is a class CONSTANT (host hit 70 ns, cache hit 209 ns,
log hit/append 232 ns). This driver keeps run_fused's structure — the
same scheduler selection, the same live-probed discrete decisions, the
same boundary bodies — and deletes ALL per-event float arithmetic:

  * Gaps are prefix-summed ONCE per thread (`np.cumsum` over the whole
    trace — NumPy dispatch amortized over ~17k events instead of paying
    it per ~28-event run, which scripts/dispatch_overhead.py shows is a
    net loss on this box).
  * Fast events (host/cache/log hits, log appends) bump one small-int
    class counter each. Nothing else.
  * `t` is only *materialized* at boundaries (miss, write miss, log
    fill, promotion, window end, vector-regime delegation):

        t = anchor_t + (gp[j] - gp[anchor])        # gap prefix diff
            + n_host*lat_host + n_cache*lat_cache + n_log*lat_log

    after which the boundary body runs verbatim from run_fused and the
    anchor re-bases. The per-class latency sums fold into the localized
    stat accumulators at the same points, so delegation to the (exact)
    batched_quantum vector path composes unchanged.

Two-tier contract (enforced by tests/test_engine_turbo.py):

  * EXACT — every discrete decision and structure: scheduling order,
    classification, park/promote/compact, GC victims and migrations,
    FTL l2p/p2l/wear, WAF, event counters, final DeviceState arrays.
    The kernel live-probes the same shared views as run_fused; only
    float *values* differ, and no discrete branch in the turbo-eligible
    regime is decided by a quantity within drift of its threshold (the
    park test is `est >= read_ns > ctx_threshold_ns` — always true when
    ctx is on; GC/promotion/log-fill triggers are integer counters).
  * APPROXIMATE — per-thread finish times, AMAT, latency percentiles:
    reassociation moves them by ~1e-12 relative (measured), bounded
    a-priori by the drift accounting below and asserted <= 1e-6 against
    the reference engine across the property sweep.

Drift accounting: each materialization is <= ~6 positive additions on a
monotone timeline, so it contributes at most a few ulps of relative
error; the gap prefix-sum contributes the standard n*eps cumsum bound.
Per thread: bound = (_FLUSH_ULPS * flushes + n_events) * eps, exported
as Stats.turbo_drift_max / turbo_drift_mean and checked against
SimConfig.turbo_rtol — a run can never silently exceed its contract.

Conflict classes refuse exactly like run_fused: fault-, QoS- and
obs-active cells and inline-only promotion policies (tpp/astriflash)
fall back to the plain scheduler around batched_quantum, which routes
every flash read through the shared Channels/Qos/FaultModel.read — the
fallback is fully bit-exact, so those runs report drift 0.0.

KEEP IN SYNC with engine.run_fused: every boundary body below is a
verbatim transcription; only the fast-event accounting differs.
"""
from __future__ import annotations

import bisect
import heapq

import numpy as np

from repro.configs.base import SimConfig
from repro.core.device_state import DIES_PER_CHANNEL
from repro.core.engine import _SPAN, BatchedMachine, batched_quantum
from repro.core.simulator import (_advance_idle_cores, _lat_bin,
                                  _run_scheduler)
from repro.core.ssd import TRANSFER_NS

_EPS = 2.220446049250313e-16  # IEEE-754 double unit roundoff (2**-52)
# ulp budget charged per t materialization: one flush is <= ~6 positive
# additions (anchor + prefix diff + three count*const folds), each
# contributing <= 1 ulp of relative error on the monotone timeline; 8
# over-counts deliberately so the exported figure stays a true bound.
_FLUSH_ULPS = 8.0

TURBO_STATS = {
    "turbo_events": 0,     # events retired by the counter-kernel fast path
    "boundary_events": 0,  # boundaries handled scalar inside the kernel
    "flushes": 0,          # t materializations (anchor re-bases)
    "fallbacks": 0,        # whole-run conflict-class refusals (exact path)
    "drift_bound_max": 0.0,   # per-thread a-priori relative error bound
    "drift_bound_mean": 0.0,
}


def reset_turbo_stats() -> None:
    TURBO_STATS["turbo_events"] = 0
    TURBO_STATS["boundary_events"] = 0
    TURBO_STATS["flushes"] = 0
    TURBO_STATS["fallbacks"] = 0
    TURBO_STATS["drift_bound_max"] = 0.0
    TURBO_STATS["drift_bound_mean"] = 0.0


# Cross-run memo of derived trace columns. gen_traces() is lru_cached, so
# repeated simulate() calls on one cell hand every Thread the *same* page
# and write ndarrays; the burst columns and gap prefix derived from them
# are pure functions of those arrays. Keying by object identity is sound
# here because each entry keeps strong references to its source arrays —
# while the entry lives, CPython cannot recycle those ids for new objects.
# (gap64 is a fresh float64 copy each run, but it is itself a pure function
# of the cached float32 gap column that travels with `page`, so the cumsum
# memoized under the page/write identity is identical across runs.)
_TRACE_MEMO: dict = {}  # (id(page), id(write)) -> (page, write, cols, gp)
_TRACE_MEMO_CAP = 64  # ~4 cached trace sets x 12 threads, with slack


def _memo_entry(th):
    """Burst columns + gap prefix for one thread, memoized across runs.

    Burst columns: one entry per maximal run of a repeated (page, write)
    pair in the trace. The trace generators emit multi-access page
    visits, so consecutive events repeat one (page, write) pair in short
    bursts (measured avg ~2.7 on the calibration traces). Only a
    boundary event can change device state, and a burst that starts as a
    host or cache hit fires none, so the turbo walks collapse whole
    bursts into single steps. `cols` is (pages, writes, lengths, starts)
    as plain Python lists: the first three are zipped for C-speed
    iteration — one tuple unpack per burst instead of per-event column
    subscripts — and `starts` (sorted event index of each burst head)
    re-anchors a window that opens mid-burst via one bisect.

    Gap prefix: gp[j] = sum(gaps[:j]), exclusive, over the same float64
    gap column the other engines iterate; memoryview indexing returns
    plain Python floats without ndarray scalar boxing."""
    key = (id(th.page), id(th.write))
    ent = _TRACE_MEMO.get(key)
    if ent is None:
        pg = th.page
        n = len(pg)
        if n == 0:
            cols = ([], [], [], [])
        else:
            bkey = (pg << 1) | th.write
            ends = np.concatenate(
                (np.flatnonzero(bkey[1:] != bkey[:-1]), [n - 1]))
            starts = np.concatenate(([0], ends[:-1] + 1))
            cols = (pg[starts].tolist(), th.write[starts].tolist(),
                    (ends - starts + 1).tolist(), starts.tolist())
        arr = np.empty(n + 1)
        arr[0] = 0.0
        np.cumsum(th.gap64, out=arr[1:])
        # per-event columns as plain lists (same layout BatchedMachine.
        # _columns builds per run); memoized here so repeat turbo runs
        # skip the tolist rebuild entirely
        pcols = (pg.tolist(), th.line.tolist(), th.write.tolist())
        if len(_TRACE_MEMO) >= _TRACE_MEMO_CAP:
            _TRACE_MEMO.clear()
        ent = (pg, th.write, cols, memoryview(arr), pcols)
        _TRACE_MEMO[key] = ent
    return ent


def _gap_prefix(gpref: dict, th):
    """Per-run, per-tid view of the memoized gap prefix."""
    gp = gpref.get(th.tid)
    if gp is None:
        gp = _memo_entry(th)[3]
        gpref[th.tid] = gp
    return gp


def _burst_cols(bref: dict, th):
    """Per-run, per-tid view of the memoized burst columns."""
    cols = bref.get(th.tid)
    if cols is None:
        cols = _memo_entry(th)[2]
        bref[th.tid] = cols
    return cols


def _finalize_drift(cfg: SimConfig, threads, flushes, gpref) -> None:
    """Fold per-thread flush counts into the exported drift bound and
    enforce the configured contract. Threads that never touched the
    prefix (fully delegated to the exact vector path) carry only their
    flush term; zero flushes + no prefix = exactly 0.0."""
    bounds = []
    for ti, th in enumerate(threads):
        pre = th.n if th.tid in gpref else 0
        bounds.append((_FLUSH_ULPS * flushes[ti] + pre) * _EPS)
    bmax = max(bounds) if bounds else 0.0
    TURBO_STATS["drift_bound_max"] = bmax
    TURBO_STATS["drift_bound_mean"] = (
        sum(bounds) / len(bounds) if bounds else 0.0)
    TURBO_STATS["flushes"] += sum(flushes)
    if bmax > cfg.turbo_rtol:
        raise ValueError(
            f"turbo drift bound {bmax:.3e} exceeds SimConfig.turbo_rtol="
            f"{cfg.turbo_rtol:.1e}; raise turbo_rtol or use "
            f"engine='batched' for bit-exact timelines")


def _make_dram_quantum(cfg: SimConfig, gpref: dict, flushes: list):
    """dram-only quantum: the whole remaining trace in O(1).

    Every access is a host-DRAM hit at a constant latency and nothing
    ever parks the thread, so one quantum serves the thread to
    completion: t advances by the gap prefix total plus n*host_dram_ns,
    and the read/write split comes from one vector count."""
    lat_host = cfg.host_dram_ns

    def _dram_quantum(m, _cfg, th, t, wslots):
        i, n = th.i, th.n
        k = n - i
        if k <= 0:
            return t
        st = m.stats
        gp = _gap_prefix(gpref, th)
        nw = int(np.count_nonzero(th.write[i:]))
        hs = k * lat_host
        t = t + (gp[n] - gp[i]) + hs
        st.n += k
        st.host_w += nw
        st.host_r += k - nw
        st.lat_sum += hs
        st.lat_host += hs
        flushes[th.tid] += 1
        TURBO_STATS["turbo_events"] += k
        th.i = n
        return t

    return _dram_quantum


def run_turbo(m: BatchedMachine, cfg: SimConfig, threads) -> list:
    """Fast-math fused driver — run_fused minus per-event float chains.

    KEEP IN SYNC with engine.run_fused: scheduler selection, boundary
    bodies and stat-flush protocol are verbatim copies; the fast-event
    paths replace `t += gap; acc += lat; t += lat` with one small-int
    class counter bump, reconciled at each anchor flush. Returns the
    per-core clock list."""
    if (m._inline_only or m.channels.fault is not None
            or m.channels.qos is not None
            or m.channels.obs is not None):
        # Conflict classes, same set as run_fused: the inlined flash-read
        # sites would bypass FaultModel/QosModel/ObsModel staging, and
        # inline-only promotion policies (tpp/astriflash) consume the RNG
        # per event. The plain scheduler + batched_quantum route is fully
        # bit-exact, so these runs report drift 0.0 (tested refusal).
        TURBO_STATS["fallbacks"] += 1
        return _run_scheduler(m, cfg, threads, batched_quantum)
    gpref: dict = {}
    bref: dict = {}
    tref: dict = {}  # per-run tid -> full memoized thread view
    flushes = [0] * len(threads)
    if cfg.dram_only:
        cores = _run_scheduler(m, cfg, threads,
                               _make_dram_quantum(cfg, gpref, flushes))
        _finalize_drift(cfg, threads, flushes, gpref)
        return cores
    st = m.stats
    ds = m.state
    # ---- scheduler state (verbatim from simulator._run_scheduler) ----
    n_cores = cfg.n_cores
    cores = [0.0] * n_cores
    wslots_per_core = [[] for _ in range(n_cores)]
    sched_counter = 0
    nt = len(threads)
    n_alive = nt
    vrun = [0.0] * nt
    last_sched = [0] * nt
    use_cfs = cfg.sched_policy == "CFS"
    use_random = cfg.sched_policy == "RANDOM"
    heappush, heappop = heapq.heappush, heapq.heappop
    insort = bisect.insort
    wake_q = []
    if use_random:
        run_l = list(range(nt))  # all runnable at t=0, thread-index order
        rng_choice = m.rng.choice
    else:
        keys = vrun if use_cfs else last_sched
        run_q = [(0, ti) for ti in range(nt)]  # all runnable, key 0
    # ---- span environment, hoisted ONCE for the whole run ----
    (maybe_promote, compact, host, move_host, cres, cdirty, cstamp, csets,
     cway, n_sets, ways, epoch_mv, journal, promoting, skybyte_count, acc,
     promo_thr, lat_host, base, cache_idx, dram, lat_log, lat_cache,
     ctx_ns, ctx_thr, chan_bus, chan_die, n_ch, t_read, rd_busy,
     ftl_write, max_out, ctx_on, logbits, log_cap,
     l2p, loc_div, gc_from, gc_until, f_read) = m._span_env
    block_route = l2p is not None
    log_on = logbits is not None
    lat_hist = st.lat_hist
    lat_hist_w = st.lat_hist_w
    lb = _lat_bin
    journal_clear = journal.clear
    check_host = promoting or len(host) > 0
    min_run = m._min_run
    replay_lat = m._lat_cache
    # deferred host-LRU moves, same protocol as run_fused (see hflush
    # there): membership probes stay exact between flushes
    hbuf: list = []
    hbuf_app = hbuf.append

    def hflush():
        if hbuf:
            for q in reversed(dict.fromkeys(reversed(hbuf))):
                move_host(q)
            del hbuf[:]
    if log_on:
        log_active = ds.log_active
        log_get = log_active.get
    # ---- stats accumulators, localized across quanta ----
    n_acc = st.n
    host_r_n = st.host_r
    host_w_n = st.host_w
    hit_log_n = st.hit_log
    hit_cache_n = st.hit_cache
    miss_n = st.miss_flash
    ssd_w_n = st.ssd_w
    ssd_w_var_n = st.ssd_w_var
    ctx_sw_n = st.ctx_switches
    replays_n = st.replays
    lat_sum = st.lat_sum
    lat_host_acc = st.lat_host
    lat_hit_acc = st.lat_hit
    lat_miss_acc = st.lat_miss
    turbo_n = 0

    while n_alive:
        # core with the earliest time (first minimal index)
        t_now = min(cores)
        c = cores.index(t_now)
        if use_random:
            while wake_q and wake_q[0][0] <= t_now:
                insort(run_l, heappop(wake_q)[1])
            if not run_l:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = rng_choice(run_l)
            run_l.remove(ti)
        else:
            while wake_q and wake_q[0][0] <= t_now:
                ti = heappop(wake_q)[1]
                heappush(run_q, (keys[ti], ti))
            if not run_q:
                _advance_idle_cores(cores, t_now, wake_q[0][0])
                continue
            ti = heappop(run_q)[1]
        sched_counter += 1
        last_sched[ti] = sched_counter
        th = threads[ti]
        rdy = th.ready
        t = t_now if t_now >= rdy else rdy
        t0 = t
        wslots = wslots_per_core[c]
        flN = flushes[ti]
        # ---------------- one fast-math scheduling quantum ----------------
        i = th.i
        n = th.n
        if th.replay:
            # inlined _replay_prologue: the replayed access is charged as
            # an SSD DRAM hit; identical accounting order
            th.replay = False
            t += replay_lat
            n_acc += 1
            lat_sum += replay_lat
            hit_cache_n += 1
            lat_hit_acc += replay_lat
            replays_n += 1
            i += 1
        journal_clear()  # only this quantum's boundary bumps matter
        blocked = False
        while i < n and not blocked:
            if m.runlen >= min_run:
                # vector regime: flush localized stats, hand the rest of
                # the quantum to the (exact) chunked vector machinery
                th.i = i
                st.n = n_acc
                st.host_r = host_r_n
                st.host_w = host_w_n
                st.hit_log = hit_log_n
                st.hit_cache = hit_cache_n
                st.miss_flash = miss_n
                st.ssd_w = ssd_w_n
                st.ssd_w_var = ssd_w_var_n
                st.ctx_switches = ctx_sw_n
                st.replays = replays_n
                st.lat_sum = lat_sum
                st.lat_host = lat_host_acc
                st.lat_hit = lat_hit_acc
                st.lat_miss = lat_miss_acc
                hflush()  # vector path reads and reorders the host LRU
                t = batched_quantum(m, cfg, th, t, wslots)
                n_acc = st.n
                host_r_n = st.host_r
                host_w_n = st.host_w
                hit_log_n = st.hit_log
                hit_cache_n = st.hit_cache
                miss_n = st.miss_flash
                ssd_w_n = st.ssd_w
                ssd_w_var_n = st.ssd_w_var
                ctx_sw_n = st.ctx_switches
                replays_n = st.replays
                lat_sum = st.lat_sum
                lat_host_acc = st.lat_host
                lat_hit_acc = st.lat_hit
                lat_miss_acc = st.lat_miss
                i = th.i
                if log_on:  # compaction may have swapped the active dict
                    log_active = ds.log_active
                    log_get = log_active.get
                break
            # ---- turbo kernel: one counter-batched window ----
            rint = int(m.runlen)
            if ctx_on:
                # wider than run_fused's window: the walk re-anchors (one
                # float flush) per window, so fewer, larger windows mean
                # fewer reassociation points AND fewer prologues; park /
                # vector-regime exits are per-event decisions, so window
                # size is mechanically neutral
                stop = i + 4 * rint + 192
            else:
                stop = i + _SPAN
            if stop > n:
                stop = n
            tv = tref.get(ti)
            if tv is None:
                ent = _memo_entry(th)
                pages, lines, writes = ent[4]
                gp = ent[3]
                bp, bw, bl, bs = ent[2]
                tv = (pages, lines, writes, gp, bp, bw, bl, bs)
                tref[ti] = tv
                # finalize's per-thread drift accounting keys off gpref
                gpref[ti] = gp
            else:
                pages, lines, writes, gp, bp, bw, bl, bs = tv
            jb = bisect.bisect_right(bs, i) - 1  # burst containing event i
            lim = stop - i
            # exact burst slice for [i, stop): the lengths column is a
            # fresh slice copy, so the window-edge adjustments (events of
            # the head burst already consumed by an earlier window; tail
            # burst clipped at the window end) mutate it directly — the
            # walks then need no per-burst offset/clamp scaffolding and
            # no k-versus-lim exit check (the zip simply runs dry)
            jb_hi = bisect.bisect_right(bs, stop - 1)
            bls = bl[jb:jb_hi]
            if jb >= 0 and i > bs[jb]:
                bls[0] -= i - bs[jb]
            end = bs[jb_hi] if jb_hi < len(bs) else n
            if end > stop:
                bls[-1] -= end - stop
            cclk = ds.cache_clock
            k = 0
            slow_n = 0
            bnd_n = 0
            hp_last = -1  # host-LRU dedupe: consecutive touches are no-ops
            # anchor: counters cover fast events in [a, i+k), gp covers
            # gaps in [a, i+k) — a boundary at i+k-1 never bumps a fast
            # counter, so one formula materializes t at both boundary
            # entry (gap charged, latency pending) and window end
            a = i
            at = t
            anhr = anhw = ancr = ancw = anlr = anlw = 0
            if not log_on:
                # ============== collapsed no-write-log walk ==============
                # Iterate bursts, not events (_burst_cols): one C-level
                # zip unpack per maximal same-(page, write) run. A burst
                # that opens as a host or cache hit fires no boundary,
                # so it collapses into ONE scalar step with no per-event
                # work: the LRU stamp keeps only the last touch
                # (intermediate stamps are unobservable without a
                # boundary), the dirty bit is sticky, and the class and
                # promotion counters fold by plain integer adds — the
                # anchor flush formula reads only the counts, so the
                # folded timeline is bit-identical to the per-event one.
                # Any burst that could fire a boundary (promotion
                # crossing, flash miss) processes ONE verbatim per-event
                # step, then re-enters the classifier for the remainder
                # — which usually folds, because the boundary itself
                # made the page resident (miss insert) or moved it to
                # the host (promotion). KEEP IN SYNC with run_fused's
                # no-log loop: single-event bodies are verbatim copies.
                for p, w, m_r in zip(bp[jb:jb_hi], bw[jb:jb_hi], bls):
                    while True:  # re-classify after a per-event step
                        if check_host and p in host:
                            if p != hp_last:
                                hbuf_app(p)  # deferred LRU move
                                hp_last = p
                            if w:
                                anhw += m_r
                            else:
                                anhr += m_r
                            k += m_r
                            break
                        if cres[p]:
                            if promoting:
                                cnt2 = acc[p] + m_r
                                if cnt2 >= promo_thr:
                                    # crossing inside the burst: one
                                    # verbatim per-event hit step
                                    k += 1
                                    cclk += 1
                                    cstamp[p] = cclk  # LRU touch
                                    if w:
                                        cdirty[p] = True  # mark_dirty
                                    cnt2 = acc[p] + 1
                                    if cnt2 >= promo_thr:  # resident
                                        # promotion reads `now`:
                                        # materialize t
                                        hs = (anhr + anhw) * lat_host
                                        cs = (ancr + ancw) * lat_cache
                                        t = (at + (gp[i + k] - gp[a])
                                             + hs + cs)
                                        host_r_n += anhr
                                        host_w_n += anhw
                                        hit_cache_n += ancr
                                        ssd_w_n += ancw
                                        lat_sum += hs + cs
                                        lat_host_acc += hs
                                        lat_hit_acc += cs
                                        anhr = anhw = ancr = ancw = 0
                                        a = i + k
                                        at = t
                                        flN += 1
                                        hflush()
                                        ds.cache_clock = cclk
                                        maybe_promote(p, t)
                                        cclk = ds.cache_clock
                                        hp_last = -1
                                        bnd_n += 1
                                    else:
                                        acc[p] = cnt2
                                    if w:
                                        ancw += 1
                                    else:
                                        ancr += 1
                                    m_r -= 1
                                    if m_r:
                                        continue  # p may be host now
                                    break
                                acc[p] = cnt2
                            cclk += m_r
                            cstamp[p] = cclk  # last touch of the burst
                            if w:
                                cdirty[p] = True
                                ancw += m_r
                            else:
                                ancr += m_r
                            k += m_r
                            break
                        # ---- boundary: materialize t, fold counters ----
                        k += 1
                        hs = (anhr + anhw) * lat_host
                        cs = (ancr + ancw) * lat_cache
                        t = at + (gp[i + k] - gp[a]) + hs + cs
                        host_r_n += anhr
                        host_w_n += anhw
                        hit_cache_n += ancr
                        ssd_w_n += ancw
                        lat_sum += hs + cs
                        lat_host_acc += hs
                        lat_hit_acc += cs
                        anhr = anhw = ancr = ancw = 0
                        flN += 1
                        if w:
                            # Base-CSSD write miss: posted store,
                            # background page fetch in a write slot
                            # (verbatim run_fused)
                            stall = 0.0
                            if len(wslots) >= max_out:
                                oldest = min(wslots)
                                wslots.remove(oldest)
                                if oldest > t:
                                    stall = oldest - t
                            if block_route:
                                blk = l2p[p] // loc_div
                                ch = blk % n_ch
                                dd = (blk // n_ch) % DIES_PER_CHANNEL
                            else:
                                ch = (p * 1103515245 + 12345) % n_ch
                                dd = (p // n_ch) % DIES_PER_CHANNEL
                            die = chan_die[ch]
                            now2 = t + stall
                            dv = die[dd]
                            # background fetch: no GC-pause attribution
                            sensed = (dv if dv > now2 else now2) + t_read
                            bv = chan_bus[ch]
                            done = (sensed if sensed > bv else bv) \
                                + TRANSFER_NS
                            die[dd] = sensed
                            chan_bus[ch] = done
                            ds.chan_busy_ns += rd_busy
                            ds.flash_reads += 1
                            wslots.append(done)
                            # inlined DataCache.insert(p, True) +
                            # write-back (KEEP IN SYNC with _insert_miss)
                            row = csets[p % n_sets]
                            vw = 0
                            vp = -1
                            vs = None
                            for w2 in range(ways):
                                q = row[w2]
                                if q < 0:
                                    vw = w2
                                    vp = -1
                                    break
                                sq = cstamp[q]
                                if vs is None or sq < vs:
                                    vs = sq
                                    vw = w2
                                    vp = q
                            ec = ds.epoch_clock
                            ev_dirty = False
                            if vp >= 0:
                                ev_dirty = cdirty[vp]
                                cres[vp] = False
                                cway[vp] = -1
                                ec += 1
                                epoch_mv[vp] = ec
                                journal.append(vp)
                            row[vw] = p
                            cway[p] = vw
                            cres[p] = True
                            cdirty[p] = True
                            cclk += 1
                            cstamp[p] = cclk
                            ec += 1
                            epoch_mv[p] = ec
                            journal.append(p)
                            ds.epoch_clock = ec
                            if ev_dirty:
                                ftl_write(t, vp)  # full program incl. GC
                                st.flash_write_pages += 1
                            bnd_n += 1
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr:  # just inserted
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                    bnd_n += 1
                                else:
                                    acc[p] = cnt2
                            ssd_w_n += 1
                            lat = stall + base + cache_idx + dram
                            if stall > 0.0:  # variable latency
                                ssd_w_var_n += 1
                                lat_hist_w[lb(lat)] += 1
                            lat_sum += lat
                            lat_hit_acc += lat
                            t += lat
                            a = i + k
                            at = t
                            m_r -= 1
                            if m_r:
                                continue  # remainder now cache-resident
                            break
                        # ---- flash read miss (Algorithm 1 park) ----
                        if block_route:
                            blk = l2p[p] // loc_div
                            ch = blk % n_ch
                            dd = (blk // n_ch) % DIES_PER_CHANNEL
                        else:
                            ch = (p * 1103515245 + 12345) % n_ch
                            dd = (p // n_ch) % DIES_PER_CHANNEL
                        die = chan_die[ch]
                        dv = die[dd]
                        bv = chan_bus[ch]
                        if ctx_on:  # inlined Channels.estimate
                            dw = dv - t
                            bw2 = bv - t
                            wait = dw if dw > bw2 else bw2
                            est = (wait if wait > 0.0 else 0.0) + t_read
                        if dv > t:  # GC-pause attribution
                            gu = gc_until[ch][dd]
                            if gu > t:
                                gf = gc_from[ch][dd]
                                lo2 = t if t > gf else gf
                                hi2 = dv if dv < gu else gu
                                pause = hi2 - lo2
                                if pause > 0.0:
                                    ds.gc_stall_events += 1
                                    ds.gc_pause_ns_total += pause
                                    if pause > ds.gc_pause_max_ns:
                                        ds.gc_pause_max_ns = pause
                        # inlined Channels.read
                        sensed = (dv if dv > t else t) + t_read
                        done = (sensed if sensed > bv else bv) \
                            + TRANSFER_NS
                        die[dd] = sensed
                        chan_bus[ch] = done
                        ds.chan_busy_ns += rd_busy
                        ds.flash_reads += 1
                        # inlined DataCache.insert(p, False) + write-back
                        # (KEEP IN SYNC with _insert_miss)
                        row = csets[p % n_sets]
                        vw = 0
                        vp = -1
                        vs = None
                        for w2 in range(ways):
                            q = row[w2]
                            if q < 0:
                                vw = w2
                                vp = -1
                                break
                            sq = cstamp[q]
                            if vs is None or sq < vs:
                                vs = sq
                                vw = w2
                                vp = q
                        ec = ds.epoch_clock
                        ev_dirty = False
                        if vp >= 0:
                            ev_dirty = cdirty[vp]
                            cres[vp] = False
                            cway[vp] = -1
                            ec += 1
                            epoch_mv[vp] = ec
                            journal.append(vp)
                        row[vw] = p
                        cway[p] = vw
                        cres[p] = True
                        cdirty[p] = False
                        cclk += 1
                        cstamp[p] = cclk
                        ec += 1
                        epoch_mv[p] = ec
                        journal.append(p)
                        ds.epoch_clock = ec
                        if ev_dirty:
                            ftl_write(t, vp)  # full program incl. GC
                            st.flash_write_pages += 1
                        if ctx_on and est > ctx_thr:
                            ctx_sw_n += 1
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr:  # just inserted
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                else:
                                    acc[p] = cnt2
                            slow_n += 1
                            th.ready = done
                            th.replay = True
                            t += ctx_ns
                            k -= 1  # squashed access: replayed on wake
                            blocked = True
                            break
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # just inserted
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        bnd_n += 1
                        lat = (done - t) + base + cache_idx + dram
                        miss_n += 1
                        lat_hist[lb(lat)] += 1
                        lat_sum += lat
                        lat_miss_acc += lat
                        t += lat
                        a = i + k
                        at = t
                        m_r -= 1
                        if m_r:
                            continue  # remainder now cache-resident
                        break
                    if blocked:
                        break
                # window end: materialize the tail run. Counters may be
                # pending even when a == i+k (a promotion on the last
                # event re-bases the anchor BEFORE its class counter
                # bumps), so the guard checks both.
                if not blocked and (a != i + k or anhr or anhw
                                    or ancr or ancw):
                    hs = (anhr + anhw) * lat_host
                    cs = (ancr + ancw) * lat_cache
                    t = at + (gp[i + k] - gp[a]) + hs + cs
                    host_r_n += anhr
                    host_w_n += anhw
                    hit_cache_n += ancr
                    ssd_w_n += ancw
                    lat_sum += hs + cs
                    lat_host_acc += hs
                    lat_hit_acc += cs
                    flN += 1
            else:
                # ============== collapsed write-log walk ==============
                # Same burst-zip collapse, specialized for the write-log
                # classes. The write flag is constant within a burst, so
                # an append burst folds its lines through one inline
                # membership loop over the burst's line slice (duplicate
                # lines are exact no-ops that still charge one lat_log
                # each), and a read burst resolves its log-line hits the
                # same way. No helper calls in the folds: on short
                # bursts a single C-call (dict.fromkeys, sum/map) costs
                # more than the scalar loop it replaces. A fold is
                # refused — one verbatim per-event step runs, then the
                # classifier re-enters — whenever the burst could fire a
                # boundary: a log-capacity fill, a promotion-threshold
                # crossing against a cache-resident page (appends and
                # log/cache hits never change residency, so the refusal
                # test is stable across the burst), or a flash miss.
                # KEEP IN SYNC with run_fused's log loop, including the
                # active-buffer memo (reset on compaction, promotion,
                # and miss).
                an = ds.log_active_n
                lp_memo = -1
                e_memo = None
                for p, w, m_r in zip(bp[jb:jb_hi], bw[jb:jb_hi], bls):
                    while True:  # re-classify after a per-event step
                        if check_host and p in host:
                            if p != hp_last:
                                hbuf_app(p)  # deferred LRU move
                                hp_last = p
                            if w:
                                anhw += m_r
                            else:
                                anhr += m_r
                            k += m_r
                            break
                        if p == lp_memo:
                            e = e_memo
                        else:
                            e = log_get(p)
                            lp_memo = p
                            e_memo = e
                        if w:
                            if (m_r > 1 and an + m_r < log_cap
                                    and not (promoting and cres[p]
                                             and acc[p] + m_r
                                             >= promo_thr)):
                                # folded append burst: the active count
                                # grows by at most m_r (stays below
                                # capacity) and no promotion can fire
                                if e is None:
                                    e = log_active[p] = {}
                                    e_memo = e
                                x = i + k
                                bits = logbits[p]
                                for l in lines[x:x + m_r]:
                                    if l not in e:
                                        e[l] = True
                                        bits |= 1 << l
                                        an += 1
                                logbits[p] = bits
                                if promoting:
                                    acc[p] = acc[p] + m_r
                                anlw += m_r
                                k += m_r
                                break
                            # verbatim per-event append body
                            l = lines[i + k]
                            k += 1
                            # cacheline log append -> compact if full
                            if e is None or l not in e:
                                if e is None:
                                    e = log_active[p] = {}
                                    e_memo = e
                                e[l] = True
                                logbits[p] = logbits[p] | (1 << l)
                                an += 1
                                if an >= log_cap:  # filled: drain
                                    # compaction reads `now`:
                                    # materialize t
                                    hs = (anhr + anhw) * lat_host
                                    cs = ancr * lat_cache
                                    ls = (anlr + anlw) * lat_log
                                    t = (at + (gp[i + k] - gp[a])
                                         + hs + cs + ls)
                                    host_r_n += anhr
                                    host_w_n += anhw
                                    hit_cache_n += ancr
                                    hit_log_n += anlr
                                    ssd_w_n += anlw
                                    lat_sum += hs + cs + ls
                                    lat_host_acc += hs
                                    lat_hit_acc += cs + ls
                                    anhr = anhw = ancr = anlr = anlw = 0
                                    a = i + k
                                    at = t
                                    flN += 1
                                    hflush()
                                    ds.log_active_n = an
                                    compact(t)
                                    log_active = ds.log_active
                                    log_get = log_active.get
                                    an = ds.log_active_n
                                    lp_memo = -1
                                    e_memo = None
                                    bnd_n += 1
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr and cres[p]:
                                    hs = (anhr + anhw) * lat_host
                                    cs = ancr * lat_cache
                                    ls = (anlr + anlw) * lat_log
                                    t = (at + (gp[i + k] - gp[a])
                                         + hs + cs + ls)
                                    host_r_n += anhr
                                    host_w_n += anhw
                                    hit_cache_n += ancr
                                    hit_log_n += anlr
                                    ssd_w_n += anlw
                                    lat_sum += hs + cs + ls
                                    lat_host_acc += hs
                                    lat_hit_acc += cs + ls
                                    anhr = anhw = ancr = anlr = anlw = 0
                                    a = i + k
                                    at = t
                                    flN += 1
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                    lp_memo = -1
                                    e_memo = None
                                    bnd_n += 1
                                else:
                                    acc[p] = cnt2
                            anlw += 1
                            m_r -= 1
                            if m_r:
                                continue  # compaction/promotion re-check
                            break
                        # ---- read burst ----
                        if m_r > 1:
                            in_cache = cres[p]
                            lhits = 0
                            if e is not None:
                                x = i + k
                                for l in lines[x:x + m_r]:
                                    if l in e:
                                        lhits += 1
                            if (lhits == m_r or in_cache) and not (
                                    promoting and in_cache
                                    and acc[p] + m_r >= promo_thr):
                                # folded read burst: every event lands
                                # in the log or the cache and no
                                # promotion can fire (a crossing without
                                # cache residency never fires — both hit
                                # classes require it)
                                nc = m_r - lhits
                                if nc:
                                    cclk += nc
                                    cstamp[p] = cclk  # last cache touch
                                    ancr += nc
                                anlr += lhits
                                if promoting:
                                    acc[p] = acc[p] + m_r
                                k += m_r
                                break
                        # verbatim per-event read body
                        l = lines[i + k]
                        k += 1
                        if e is not None and l in e:
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr and cres[p]:
                                    hs = (anhr + anhw) * lat_host
                                    cs = ancr * lat_cache
                                    ls = (anlr + anlw) * lat_log
                                    t = (at + (gp[i + k] - gp[a])
                                         + hs + cs + ls)
                                    host_r_n += anhr
                                    host_w_n += anhw
                                    hit_cache_n += ancr
                                    hit_log_n += anlr
                                    ssd_w_n += anlw
                                    lat_sum += hs + cs + ls
                                    lat_host_acc += hs
                                    lat_hit_acc += cs + ls
                                    anhr = anhw = ancr = anlr = anlw = 0
                                    a = i + k
                                    at = t
                                    flN += 1
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                    lp_memo = -1
                                    e_memo = None
                                    bnd_n += 1
                                else:
                                    acc[p] = cnt2
                            anlr += 1
                            m_r -= 1
                            if m_r:
                                continue  # promotion may re-route
                            break
                        if cres[p]:
                            cclk += 1
                            cstamp[p] = cclk  # LRU touch
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr:  # resident
                                    hs = (anhr + anhw) * lat_host
                                    cs = ancr * lat_cache
                                    ls = (anlr + anlw) * lat_log
                                    t = (at + (gp[i + k] - gp[a])
                                         + hs + cs + ls)
                                    host_r_n += anhr
                                    host_w_n += anhw
                                    hit_cache_n += ancr
                                    hit_log_n += anlr
                                    ssd_w_n += anlw
                                    lat_sum += hs + cs + ls
                                    lat_host_acc += hs
                                    lat_hit_acc += cs + ls
                                    anhr = anhw = ancr = anlr = anlw = 0
                                    a = i + k
                                    at = t
                                    flN += 1
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                    lp_memo = -1
                                    e_memo = None
                                    bnd_n += 1
                                else:
                                    acc[p] = cnt2
                            ancr += 1
                            m_r -= 1
                            if m_r:
                                continue  # promotion may re-route
                            break
                        # ---- boundary: materialize t, fold counters ----
                        hs = (anhr + anhw) * lat_host
                        cs = ancr * lat_cache
                        ls = (anlr + anlw) * lat_log
                        t = at + (gp[i + k] - gp[a]) + hs + cs + ls
                        host_r_n += anhr
                        host_w_n += anhw
                        hit_cache_n += ancr
                        hit_log_n += anlr
                        ssd_w_n += anlw
                        lat_sum += hs + cs + ls
                        lat_host_acc += hs
                        lat_hit_acc += cs + ls
                        anhr = anhw = ancr = anlr = anlw = 0
                        flN += 1
                        # ---- flash read miss (Algorithm 1 park) ----
                        if block_route:
                            blk = l2p[p] // loc_div
                            ch = blk % n_ch
                            dd = (blk // n_ch) % DIES_PER_CHANNEL
                        else:
                            ch = (p * 1103515245 + 12345) % n_ch
                            dd = (p // n_ch) % DIES_PER_CHANNEL
                        die = chan_die[ch]
                        dv = die[dd]
                        bv = chan_bus[ch]
                        if ctx_on:  # inlined Channels.estimate
                            dw = dv - t
                            bw2 = bv - t
                            wait = dw if dw > bw2 else bw2
                            est = (wait if wait > 0.0 else 0.0) + t_read
                        if dv > t:  # GC-pause attribution
                            gu = gc_until[ch][dd]
                            if gu > t:
                                gf = gc_from[ch][dd]
                                lo2 = t if t > gf else gf
                                hi2 = dv if dv < gu else gu
                                pause = hi2 - lo2
                                if pause > 0.0:
                                    ds.gc_stall_events += 1
                                    ds.gc_pause_ns_total += pause
                                    if pause > ds.gc_pause_max_ns:
                                        ds.gc_pause_max_ns = pause
                        # inlined Channels.read
                        sensed = (dv if dv > t else t) + t_read
                        done = (sensed if sensed > bv else bv) \
                            + TRANSFER_NS
                        die[dd] = sensed
                        chan_bus[ch] = done
                        ds.chan_busy_ns += rd_busy
                        ds.flash_reads += 1
                        # inlined DataCache.insert(p, False) + write-back
                        # (KEEP IN SYNC with _insert_miss)
                        row = csets[p % n_sets]
                        vw = 0
                        vp = -1
                        vs = None
                        for w2 in range(ways):
                            q = row[w2]
                            if q < 0:
                                vw = w2
                                vp = -1
                                break
                            sq = cstamp[q]
                            if vs is None or sq < vs:
                                vs = sq
                                vw = w2
                                vp = q
                        ec = ds.epoch_clock
                        ev_dirty = False
                        if vp >= 0:
                            ev_dirty = cdirty[vp]
                            cres[vp] = False
                            cway[vp] = -1
                            ec += 1
                            epoch_mv[vp] = ec
                            journal.append(vp)
                        row[vw] = p
                        cway[p] = vw
                        cres[p] = True
                        cdirty[p] = False
                        cclk += 1
                        cstamp[p] = cclk
                        ec += 1
                        epoch_mv[p] = ec
                        journal.append(p)
                        ds.epoch_clock = ec
                        if ev_dirty:
                            ftl_write(t, vp)  # full program incl. GC
                            st.flash_write_pages += 1
                        lp_memo = -1  # write-back/GC may touch log state
                        e_memo = None
                        if ctx_on and est > ctx_thr:
                            ctx_sw_n += 1
                            if promoting:
                                cnt2 = acc[p] + 1
                                if cnt2 >= promo_thr:  # just inserted
                                    hflush()
                                    ds.cache_clock = cclk
                                    maybe_promote(p, t)
                                    cclk = ds.cache_clock
                                    hp_last = -1
                                else:
                                    acc[p] = cnt2
                            slow_n += 1
                            th.ready = done
                            th.replay = True
                            t += ctx_ns
                            k -= 1  # squashed access: replayed on wake
                            blocked = True
                            break
                        if promoting:
                            cnt2 = acc[p] + 1
                            if cnt2 >= promo_thr:  # just inserted
                                hflush()
                                ds.cache_clock = cclk
                                maybe_promote(p, t)
                                cclk = ds.cache_clock
                                hp_last = -1
                                bnd_n += 1
                            else:
                                acc[p] = cnt2
                        bnd_n += 1
                        lat = (done - t) + base + cache_idx + dram
                        miss_n += 1
                        lat_hist[lb(lat)] += 1
                        lat_sum += lat
                        lat_miss_acc += lat
                        t += lat
                        a = i + k
                        at = t
                        m_r -= 1
                        if m_r:
                            continue  # remainder now cache-resident
                        break
                    if blocked:
                        break
                # window end: materialize the tail run (see the no-log
                # twin for why the guard also checks pending counters)
                if not blocked and (a != i + k or anhr or anhw
                                    or ancr or anlr or anlw):
                    hs = (anhr + anhw) * lat_host
                    cs = ancr * lat_cache
                    ls = (anlr + anlw) * lat_log
                    t = at + (gp[i + k] - gp[a]) + hs + cs + ls
                    host_r_n += anhr
                    host_w_n += anhw
                    hit_cache_n += ancr
                    hit_log_n += anlr
                    ssd_w_n += anlw
                    lat_sum += hs + cs + ls
                    lat_host_acc += hs
                    lat_hit_acc += cs + ls
                    flN += 1
                ds.log_active_n = an
            ds.cache_clock = cclk
            if k:
                m.runlen += 0.25 * (k / (slow_n + bnd_n + 1) - m.runlen)
            turbo_n += k
            TURBO_STATS["boundary_events"] += bnd_n
            n_acc += k
            i += k
        th.i = i
        flushes[ti] = flN
        vrun[ti] += t - t0
        if i >= n and not th.replay:
            th.done = True
            n_alive -= 1
        else:
            heappush(wake_q, (th.ready, ti))
        cores[c] = t

    hflush()  # leave the host LRU in its authoritative final order
    # final flush of the localized accumulators
    st.n = n_acc
    st.host_r = host_r_n
    st.host_w = host_w_n
    st.hit_log = hit_log_n
    st.hit_cache = hit_cache_n
    st.miss_flash = miss_n
    st.ssd_w = ssd_w_n
    st.ssd_w_var = ssd_w_var_n
    st.ctx_switches = ctx_sw_n
    st.replays = replays_n
    st.lat_sum = lat_sum
    st.lat_host = lat_host_acc
    st.lat_hit = lat_hit_acc
    st.lat_miss = lat_miss_acc
    TURBO_STATS["turbo_events"] += turbo_n
    _finalize_drift(cfg, threads, flushes, gpref)
    return cores
