import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * ok / error
  * compile seconds
  * cost_analysis flops & bytes (per-device, SPMD-partitioned program)
  * per-collective traffic estimate parsed from the partitioned HLO
  * memory_analysis output (backend-dependent; best-effort on CPU)
  * derived roofline terms (v5e constants; see benchmarks/roofline.py)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import gc
import json
import re
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, OptimConfig, get_config, shape_applicable
from repro.distributed.sharding import (
    batch_spec,
    filter_spec_for_mesh,
    param_specs,
)
from repro.launch.mesh import dp_size, make_production_mesh
from repro.launch.steps import (
    abstract_train_state,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)
from repro.models.api import ModelSpec

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    return b * int(np.prod([int(d) for d in dims.split(",") if d]))


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo: str, n_devices: int) -> Dict[str, Any]:
    """Per-device collective traffic estimate (ring schedules) from the
    SPMD-partitioned HLO text."""
    out: Dict[str, Dict[str, float]] = {}
    total = 0.0
    for line in hlo.splitlines():
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                op = c
                break
        if op is None:
            continue
        # operand shapes: everything after the opcode's opening paren
        idx = line.find(op)
        operands = line[idx:]
        shapes = _SHAPE_RE.findall(operands)
        op_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
        n = max(_group_size(line, n_devices), 2)
        ring = (n - 1) / n
        if op == "all-reduce":
            traffic = 2.0 * op_bytes * ring
        elif op == "all-gather":
            traffic = op_bytes * (n - 1)  # operand is the local shard
        else:  # reduce-scatter / all-to-all / collective-permute
            traffic = op_bytes * ring if op != "collective-permute" else op_bytes
        rec = out.setdefault(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        rec["count"] += 1
        rec["bytes"] += op_bytes
        rec["traffic"] += traffic
        total += traffic
    return {"ops": out, "traffic_bytes": total}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def _tree_shardings(mesh, spec_tree, shape_tree=None):
    """NamedShardings from a PartitionSpec tree, filtered for the mesh."""

    def one(s, shp=None):
        return NamedSharding(mesh, filter_spec_for_mesh(s, mesh, shp))

    if shape_tree is None:
        return jax.tree_util.tree_map(one, spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_map(
        lambda s, t: one(s, t.shape), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    spec = ModelSpec(cfg)
    schema = spec.schema()
    # §Perf layout profiles: REPRO_LAYOUT=dp replicates parameters and
    # spreads the batch over BOTH axes — the right layout for small models
    # whose TP collectives dwarf their compute (whisper-base, smollm).
    layout = os.environ.get("REPRO_LAYOUT", "default")
    if layout == "dp":
        rules = {k: None for k in
                 ("layers", "vocab", "embed", "heads", "kv", "ffn", "inner",
                  "experts")}
        pspecs = param_specs(schema, mesh, rules)
        bspec = P(("data", "model"))
    elif layout == "tp_only":
        # serving layout: no FSDP dim (no optimizer state to shard) —
        # params TP-sharded over "model", replicated over "data"; kills
        # the per-step weight all-gathers that dominate decode cells.
        from repro.distributed.sharding import DEFAULT_RULES

        rules = dict(DEFAULT_RULES)
        rules["embed"] = None
        pspecs = param_specs(schema, mesh, rules)
        bspec = batch_spec(mesh)
    else:
        pspecs = param_specs(schema, mesh)
        bspec = batch_spec(mesh)
    p_shardings = _tree_shardings(mesh, pspecs)
    n_dev = mesh.devices.size
    inputs = spec.input_specs(shape)

    def bshard(sds):
        return NamedSharding(mesh, filter_spec_for_mesh(
            P(*([bspec[0]] + [None] * (len(sds.shape) - 1))), mesh, sds.shape))

    if shape.kind == "train":
        mb = cfg.microbatch.get(shape_name, 8)
        dp = dp_size(mesh)
        accum = max(1, shape.global_batch // max(mb * dp, 1))
        while shape.global_batch % accum or (shape.global_batch // accum) % dp:
            accum -= 1
        step = build_train_step(spec, OptimConfig(), accum_steps=accum)
        state = abstract_train_state(spec)
        opt_sh = jax.tree_util.tree_map(
            lambda _: None, state["opt"], is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        )
        state_sh = {
            "params": p_shardings,
            "opt": type(state["opt"])(
                NamedSharding(mesh, P()),
                p_shardings, p_shardings, p_shardings,
            ),
        }
        batch_sh = {k: bshard(v) for k, v in inputs.items()}
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=0)
        args = (state, inputs)
        extra = {"accum_steps": accum}
    elif shape.kind == "prefill":
        step = build_prefill_step(spec)
        in_sh = [p_shardings, bshard(inputs["tokens"])]
        args = [spec.abstract_params(), inputs["tokens"]]
        if "frontend" in inputs:
            in_sh.append(bshard(inputs["frontend"]))
            args.append(inputs["frontend"])
        jitted = jax.jit(step, in_shardings=tuple(in_sh))
        args = tuple(args)
        extra = {}
    else:  # decode
        step = build_serve_step(spec)
        cache_sp = spec.cache_pspec()
        cache_specs = inputs["cache"]
        cache_sh = _tree_shardings(
            mesh,
            {k: cache_sp[k] for k in cache_specs},
            cache_specs,
        )
        jitted = jax.jit(
            step,
            in_shardings=(
                p_shardings,
                cache_sh,
                bshard(inputs["tokens"]),
                NamedSharding(mesh, P()),
            ),
            donate_argnums=1,
        )
        args = (spec.abstract_params(), cache_specs, inputs["tokens"], inputs["pos"])
        extra = {}

    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, [int(s) for s in mesh.devices.shape])),
        "n_devices": int(n_dev),
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        **extra,
    }
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost_analysis"] = {
            k: float(v)
            for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")
            )
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            a: int(getattr(ma, a))
            for a in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(ma, a)
        } or str(ma)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)
    try:
        from repro.launch import hlo_analysis

        hlo = compiled.as_text()
        rec["hlo"] = hlo_analysis.analyze(hlo)  # loop-aware per-device costs
        rec["collectives"] = parse_collectives(hlo, n_devices=int(n_dev))
        rec["hlo_bytes"] = len(hlo)
        del hlo
    except Exception as e:  # pragma: no cover
        rec["collectives_error"] = str(e)
    del compiled, lowered, jitted
    gc.collect()
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: Path, force=False) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    path = outdir / mesh_kind / f"{arch}__{shape_name}.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    if path.exists() and not force:
        return json.loads(path.read_text())
    applicable, why = shape_applicable(cfg, shape)
    if not applicable:
        rec = {"arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
               "skipped": True, "reason": why}
        path.write_text(json.dumps(rec, indent=2))
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        with mesh:
            rec = lower_cell(arch, shape_name, mesh)
        rec["ok"] = True
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "ok": False, "error": f"{type(e).__name__}: {e}"}
    rec["mesh_kind"] = mesh_kind
    path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    outdir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = n_skip = 0
    for mesh_kind in meshes:
        for a, s in cells:
            t0 = time.time()
            rec = run_cell(a, s, mesh_kind, outdir, force=args.force)
            dt = time.time() - t0
            if rec.get("skipped"):
                tag, n_skip = "SKIP", n_skip + 1
            elif rec.get("ok"):
                tag, n_ok = "OK", n_ok + 1
            else:
                tag, n_fail = "FAIL", n_fail + 1
            print(
                f"[{tag}] {mesh_kind:6s} {a:24s} {s:12s} {dt:6.1f}s "
                f"{rec.get('error', '')[:120]}",
                flush=True,
            )
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
