"""Step builders: train_step / prefill_step / serve_step.

These close over a ModelSpec + OptimConfig and are what gets jitted by the
launchers and the dry-run. Distribution enters only through in/out
shardings supplied at jit time plus the shard_hints inside the models.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig
from repro.models.api import ModelSpec
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.grad_compress import error_feedback_update
from repro.optim.schedules import cosine_schedule

Pytree = Any


def make_train_state(spec: ModelSpec, rng: jax.Array, compress: bool = False):
    params = spec.init(rng)
    state = {"params": params, "opt": adamw_init(params)}
    if compress:
        state["residual"] = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params
        )
    return state


def abstract_train_state(spec: ModelSpec, compress: bool = False):
    params = spec.abstract_params()
    f32like = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t
    )
    state = {
        "params": params,
        "opt": AdamWState(
            jax.ShapeDtypeStruct((), jnp.int32), f32like(params), f32like(params), f32like(params)
        ),
    }
    if compress:
        state["residual"] = f32like(params)
    return state


def build_train_step(
    spec: ModelSpec,
    optim: OptimConfig,
    accum_steps: int = 1,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient accumulation: the global batch is split into ``accum_steps``
    microbatches via lax.scan (keeps HLO O(1) in accum depth).
    """
    compress = optim.compress_grads

    def train_step(state: Dict[str, Pytree], batch: Dict[str, jax.Array]):
        params = state["params"]

        def split(t):
            B = t.shape[0]
            return t.reshape(accum_steps, B // accum_steps, *t.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}

        def gfn(p, mb):
            return spec.loss(p, mb)

        zero_g = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params
        )

        def acc_body(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(gfn, has_aux=True)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (g_acc, loss_acc + metrics["loss"]), ()

        (g_sum, loss_sum), _ = jax.lax.scan(
            acc_body, (zero_g, jnp.float32(0.0)), micro
        )
        grads = jax.tree_util.tree_map(lambda g: g / accum_steps, g_sum)
        loss = loss_sum / accum_steps

        new_state = dict(state)
        if compress:
            grads, new_res = error_feedback_update(grads, state["residual"])
            new_state["residual"] = new_res
        lr = cosine_schedule(optim, state["opt"].step)
        new_params, new_opt, gnorm = adamw_update(optim, state["opt"], grads, lr)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt.step}
        return new_state, metrics

    return train_step


def build_prefill_step(spec: ModelSpec) -> Callable:
    def prefill_step(params, tokens, frontend=None):
        logits, cache = spec.prefill(params, tokens, frontend)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return prefill_step


def build_serve_step(spec: ModelSpec) -> Callable:
    """One greedy decode step against the KV/state cache."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = spec.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step
