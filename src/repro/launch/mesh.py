"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Single pod : (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips
The "pod" axis carries only data-parallel gradient reduction (DCN-friendly);
"model" carries TP/EP/sequence-sharded KV (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke tests through the same code path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_size(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n
