"""Serving launcher — the SkyByte tiered-KV engine end to end.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 6 \
      --tiering skybyte
  PYTHONPATH=src python -m repro.launch.serve --tiering baseline   # dense KV

Reports the paper's metrics for the serving analogue: parks (coordinated
context switches), promoted/evicted pages (adaptive migration), compactions
and the coalescing ratio (write-log), plus tokens/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.core.tiering import TieredKVConfig
from repro.models.api import ModelSpec
from repro.serving.engine import Request, TieredEngine


def baseline_serve(spec, params, prompts, n_new):
    """Dense (non-tiered) reference serving loop: full KV per request."""
    outs = {}
    t0 = time.time()
    for rid, p in prompts.items():
        toks = jnp.asarray(p, jnp.int32)[None]
        logits, cache = spec.prefill(params, toks)
        out = [int(jnp.argmax(logits[0]))]
        S = len(p)
        maxlen = S + n_new + 4
        dc = spec.init_cache(1, maxlen)
        for kk in ("k", "v"):
            dc[kk] = jnp.pad(cache[kk], [(0, 0), (0, 0), (0, maxlen - S), (0, 0), (0, 0)])
        pos = jnp.int32(S)
        step = jax.jit(spec.decode_step)
        for _ in range(n_new - 1):
            logits, dc = step(params, dc, jnp.asarray([[out[-1]]], jnp.int32), pos)
            out.append(int(jnp.argmax(logits[0])))
            pos = pos + 1
        outs[rid] = out
    dt = time.time() - t0
    return outs, dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--tiering", choices=["skybyte", "baseline"], default="skybyte")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--hbm-pages", type=int, default=16)
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the Pallas kernels in interpret mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    assert cfg.family in ("dense", "moe", "vlm"), (
        "tiered serving demo targets GQA decoder families; "
        f"{cfg.family} decode runs via repro.launch.steps.build_serve_step"
    )
    spec = ModelSpec(cfg)
    params = spec.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = {
        rid: list(rng.integers(1, cfg.vocab - 1, size=args.prompt_len))
        for rid in range(args.requests)
    }

    if args.tiering == "baseline":
        outs, dt = baseline_serve(spec, params, prompts, args.new_tokens)
        total = sum(len(o) for o in outs.values())
        print(f"[serve/baseline] {total} tokens in {dt:.1f}s "
              f"({total/dt:.1f} tok/s)")
        return

    kv = TieredKVConfig(
        page_size=args.page_size,
        n_hbm_pages=args.hbm_pages,
        max_requests=max(args.requests, 2),
        max_pages_per_req=(args.prompt_len + args.new_tokens) // args.page_size + 2,
        log_slots=64,
        batch=min(4, args.requests),
        promote_pages_per_step=4,
    )
    eng = TieredEngine(spec, params, kv, use_pallas=args.use_pallas)
    t0 = time.time()
    for rid, p in prompts.items():
        eng.add_request(Request(rid=rid, prompt=[int(x) for x in p],
                                max_new_tokens=args.new_tokens))
    stats = eng.run(max_steps=5000)
    dt = time.time() - t0
    print(f"[serve/skybyte] {stats.decoded_tokens} tokens in {dt:.1f}s "
          f"({stats.decoded_tokens/dt:.1f} tok/s)")
    print(f"  parks (ctx switches)      : {stats.parks}")
    print(f"  promoted / evicted pages  : {stats.promoted_pages} / {stats.evicted_pages}")
    print(f"  compactions               : {stats.compactions}")
    print(f"  coalesce ratio (tok/page) : {stats.coalesce_ratio:.2f}")
    done = sum(r.done for r in eng.requests.values())
    print(f"  completed requests        : {done}/{len(eng.requests)}")


if __name__ == "__main__":
    main()
