"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --seq 256 --batch 8

Runs end-to-end on CPU with reduced configs; the same code path drives the
production mesh (the dry-run proves every full arch x shape lowers and
compiles on it). Features exercised here:
  * jitted train_step with gradient accumulation
  * checkpoint/restart (--resume; --fail-at N simulates a mid-run crash and
    recovers from the latest checkpoint — the fault-tolerance drill)
  * int8 error-feedback gradient compression (--compress)
  * deterministic restart-safe data pipeline
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_IDS, OptimConfig, get_config, get_reduced
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train_step, make_train_state
from repro.models.api import ModelSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a crash at this step (recovery drill)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    spec = ModelSpec(cfg)
    optim = OptimConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps,
                        compress_grads=args.compress)
    step_fn = jax.jit(
        build_train_step(spec, optim, accum_steps=args.accum), donate_argnums=0
    )
    state = make_train_state(spec, jax.random.PRNGKey(args.seed),
                             compress=args.compress)
    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, extra, start = ckpt.restore(state)
        data.state.step = int(extra.get("data_step", start))
        print(f"[train] resumed from step {start}")

    print(f"[train] arch={cfg.name} params={spec.param_count():,} "
          f"accum={args.accum} compress={args.compress}")
    t0 = time.time()
    for step in range(start, args.steps):
        if args.fail_at and step == args.fail_at:
            print(f"[train] SIMULATED FAILURE at step {step} — restart with "
                  f"--resume to recover")
            raise SystemExit(42)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        data.state.step = step + 1
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"[train] step {step:4d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, extra={"data_step": data.state.step})
    ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
