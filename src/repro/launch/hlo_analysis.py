"""Structural analysis of SPMD-partitioned HLO text.

``compiled.cost_analysis()`` treats every ``while`` body as executed once,
which silently drops the dominant costs of scan-over-layers programs (an
88-layer scan under-counts 88x). This module re-derives the numbers the
roofline needs by walking the HLO computation graph *with loop trip-count
multiplication*:

  * dot FLOPs           — 2 * prod(output dims) * prod(contracting dims),
                          operand shapes resolved via a per-computation
                          symbol table (post-opt HLO does not inline them)
  * collective traffic  — per-device ring-model bytes for all-reduce /
                          all-gather / reduce-scatter / all-to-all /
                          collective-permute
  * heavy-op bytes      — operand+output bytes of dots and gather/scatter/
                          dynamic-slice ops (approximate HBM-traffic lower
                          bound; elementwise fusion traffic excluded)

Trip counts are parsed from each while's condition computation (scan lowers
to ``lt(iter, K)`` with literal K). Nested loops multiply.

All numbers are PER DEVICE (the partitioned module is per-device).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OPCODE_RE = re.compile(r"([a-z][\w\-\.\$]*)\(")
_COMP_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_CONST = re.compile(r"=\s*[su](?:8|16|32|64)\[\]\s*constant\((\d+)\)")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 0)
    return b * int(np.prod(_dims(dims) or [1]))


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.coll: Dict[str, Dict[str, float]] = {}
        self.calls: List[Tuple[str, str, Optional[str]]] = []
        self.max_const = 0
        self.shapes: Dict[str, List[Tuple[str, str]]] = {}  # %name -> [(dt, dims)]


def _operand_names(args: str) -> List[str]:
    """Names inside the opcode parens (post-opt HLO: bare %names)."""
    # cut at the closing paren that matches the opcode's open paren: operands
    # never contain parens in post-opt HLO, so cut at first ')'
    body = args.split(")")[0]
    return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", body)]


def _attrs(args: str) -> str:
    i = args.find(")")
    return args[i + 1 :] if i >= 0 else ""


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COMP_START.match(raw)
        if m and raw.rstrip().endswith("{") and "=" not in raw.split("(")[0]:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None or not line:
            continue
        if line == "}":
            cur = None
            continue
        eq = line.find(" = ")
        if eq < 0:
            mc = _CONST.search(line)
            if mc:
                cur.max_const = max(cur.max_const, int(mc.group(1)))
            continue
        name = line[:eq].strip()
        if name.startswith("ROOT"):
            name = name[4:].strip()
        name = name.lstrip("%")
        rhs = line[eq + 3 :]
        mo = _OPCODE_RE.search(rhs)
        if not mo:
            mc = _CONST.search(line)
            if mc:
                cur.max_const = max(cur.max_const, int(mc.group(1)))
            continue
        opcode = mo.group(1)
        rest = rhs[mo.end() :]
        out_shapes = _SHAPE_RE.findall(rhs[: mo.start()])
        cur.shapes[name] = out_shapes
        if opcode == "constant":
            mc = _CONST.search(line)
            if mc:
                cur.max_const = max(cur.max_const, int(mc.group(1)))
        attrs = _attrs(rest)
        opnames = _operand_names(rest)

        if opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", attrs)
            if body:
                cur.calls.append((body.group(1), "while", cond.group(1) if cond else None))
            continue
        for m2 in re.finditer(
            r"(?:to_apply|calls|true_computation|false_computation)=%?([\w\.\-]+)", attrs
        ):
            # to_apply of collectives is a scalar reducer: tiny, but harmless
            if opcode not in _COLLECTIVES and not opcode.startswith(
                ("all-", "reduce-scatter", "collective")
            ) and opcode not in ("reduce", "scatter", "select-and-scatter", "sort", "map"):
                cur.calls.append((m2.group(1), "call", None))
        m3 = re.search(r"branch_computations=\{([^}]*)\}", attrs)
        if m3:
            for c in m3.group(1).split(","):
                cur.calls.append((c.strip().lstrip("%"), "call", None))

        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES:
            op_bytes = sum(
                _shape_bytes(d, s)
                for nm in opnames
                for d, s in cur.shapes.get(nm, [])
            )
            if op_bytes == 0:  # fall back to output shape (all-reduce: in==out)
                op_bytes = sum(_shape_bytes(d, s) for d, s in out_shapes)
            n = _group_size(attrs)
            ring = (n - 1) / n if n > 1 else 1.0
            if base == "all-reduce":
                traffic = 2.0 * op_bytes * ring
            elif base == "all-gather":
                traffic = op_bytes * max(n - 1, 1)
            elif base == "collective-permute":
                traffic = op_bytes
            else:  # reduce-scatter / all-to-all
                traffic = op_bytes * ring
            rec = cur.coll.setdefault(base, {"count": 0, "bytes": 0.0, "traffic": 0.0})
            rec["count"] += 1
            rec["bytes"] += op_bytes
            rec["traffic"] += traffic
        elif opcode == "dot":
            contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
            lhs_shapes = cur.shapes.get(opnames[0], []) if opnames else []
            if contract and lhs_shapes:
                lhs_dims = _dims(lhs_shapes[0][1])
                cdims = _dims(contract.group(1))
                k = int(np.prod([lhs_dims[i] for i in cdims])) if cdims else 1
                out_n = int(np.prod(_dims(out_shapes[0][1]) or [1])) if out_shapes else 0
                cur.flops += 2.0 * out_n * k
            io = sum(_shape_bytes(d, s) for d, s in out_shapes)
            io += sum(
                _shape_bytes(d, s) for nm in opnames for d, s in cur.shapes.get(nm, [])
            )
            cur.bytes += io
        elif opcode == "dynamic-update-slice":
            # in-place update (donated/loop-carried buffers): traffic is the
            # written region (update operand = operand[1]), not the full
            # result tensor
            upd = cur.shapes.get(opnames[1], out_shapes) if len(opnames) > 1 else out_shapes
            cur.bytes += sum(_shape_bytes(d, s) for d, s in upd)
        elif opcode in ("gather", "scatter", "dynamic-slice"):
            cur.bytes += sum(_shape_bytes(d, s) for d, s in out_shapes)
    return comps


def _group_size(attrs: str, default: int = 2) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def analyze(hlo: str, entry: Optional[str] = None) -> Dict[str, Any]:
    comps = parse_computations(hlo)
    if not comps:
        return {"flops": 0.0, "collectives": {}, "traffic_bytes": 0.0, "bytes": 0.0}
    called = set()
    for comp in comps.values():
        for c, _, cond in comp.calls:
            called.add(c)
            if cond:
                called.add(cond)
    entries = [n for n in comps if n not in called]
    entry_name = entry or next(
        (n for n in entries if n.startswith("main")),
        entries[-1] if entries else next(iter(comps)),
    )

    memo: Dict[str, Tuple[float, float, Dict[str, Dict[str, float]]]] = {}

    def walk(name: str, depth=0) -> Tuple[float, float, Dict[str, Dict[str, float]]]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return 0.0, 0.0, {}
        flops, byts = comp.flops, comp.bytes
        coll = {k: dict(v) for k, v in comp.coll.items()}
        for callee, kind, cond in comp.calls:
            f, b, c = walk(callee, depth + 1)
            mult = 1.0
            if kind == "while":
                trip = comps.get(cond).max_const if cond and cond in comps else 0
                mult = max(trip, 1)
            flops += f * mult
            byts += b * mult
            for op, rec in c.items():
                tgt = coll.setdefault(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
                for k in ("count", "bytes", "traffic"):
                    tgt[k] += rec[k] * mult
        memo[name] = (flops, byts, coll)
        return memo[name]

    flops, byts, coll = walk(entry_name)
    return {
        "entry": entry_name,
        "flops": flops,
        "bytes": byts,
        "collectives": coll,
        "traffic_bytes": float(sum(r["traffic"] for r in coll.values())),
    }
