"""Central logging for the repro package.

One stderr handler, configured lazily on the first ``get_logger`` call
and shared by every module under the ``repro.`` / ``benchmarks.``
namespaces. The verbosity knob is the ``REPRO_LOG`` environment
variable:

    REPRO_LOG=debug   everything (per-artifact cache traffic, ...)
    REPRO_LOG=info    operational notices (trace-cache evictions, ...)
    REPRO_LOG=warn    problems only (failed cells, corrupt artifacts)

Default is ``warn``: benchmark CSV output stays clean, and the
previously logger-less modules (core/traces.py used a bare
``logging.getLogger`` with no handler, so its INFO eviction summaries
vanished) keep exactly their old visible behavior until someone opts
in. Lines are prefixed ``# `` like the orchestrator's status output, so
they stay comment-shaped when interleaved with CSV on a terminal.
"""
from __future__ import annotations

import logging
import os
import sys

_LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "warn": logging.WARNING,
    "warning": logging.WARNING,
    "error": logging.ERROR,
}

_ROOT = "repro"
_configured = False


def _env_level() -> int:
    raw = os.environ.get("REPRO_LOG", "").strip().lower()
    if raw and raw not in _LEVELS:
        # a typo'd level used to silently mean "default"; say so once
        sys.stderr.write(
            f"# repro.log: unknown REPRO_LOG={raw!r} "
            f"(want {'|'.join(sorted(set(_LEVELS) - {'warning'}))}); "
            f"using warn\n")
    return _LEVELS.get(raw, logging.WARNING)


def _configure() -> None:
    global _configured
    root = logging.getLogger(_ROOT)
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter("# %(levelname)s %(name)s: "
                                           "%(message)s"))
    root.addHandler(handler)
    root.setLevel(_env_level())
    # propagation stays ON: the stdlib root normally has no handlers (so
    # nothing double-prints), and capture tooling — pytest's caplog in
    # particular — listens at the root
    _configured = True


def get_logger(name: str) -> logging.Logger:
    """Logger for ``name``, parented under the shared ``repro`` root.

    Accepts any dotted module name: ``repro.*`` children are returned
    as-is, anything else (``benchmarks.run``, ``__main__``) is grafted
    under the root so the single handler and REPRO_LOG level apply
    uniformly.
    """
    if not _configured:
        _configure()
    if name != _ROOT and not name.startswith(_ROOT + "."):
        name = f"{_ROOT}.{name}"
    return logging.getLogger(name)


def set_level(level: str) -> None:
    """Programmatic override of the REPRO_LOG level (tests, notebooks)."""
    if not _configured:
        _configure()
    logging.getLogger(_ROOT).setLevel(_LEVELS[level.strip().lower()])
