from repro.serving.engine import Request, ServeStats, TieredEngine  # noqa: F401
