"""Continuous-batching serving engine with the SkyByte scheduler.

The engine is the OS half of the co-design: it owns policy (who runs,
what gets promoted/evicted, when the log compacts) while core/tiering.py
owns the device data path — mirroring the paper's host-OS / SSD-controller
split.

Per decode step:
  1. residency check — a request is READY iff all its KV pages are in the
     HBM pool. Non-resident requests are PARKED (the coordinated context
     switch: the predicted fetch delay, pages_missing * fetch_page_us,
     always exceeds the park threshold) and their pages are queued for
     promotion.
  2. promotion — up to ``promote_pages_per_step`` host->HBM page copies
     (the migration bandwidth budget); LRU eviction of non-scheduled
     requests' pages under pool pressure.
  3. batch — up to ``batch`` READY requests, least-served-first (CFS).
  4. decode — one paged+logged token per scheduled request (device op).
  5. compaction — when the log can't hold another step, coalesce it into
     resident pages (HBM) and parked pages (host tier), then swap-clear.

Stats mirror the simulator's so the TPU runtime can be judged with the
paper's own metrics (coalescing ratio, switch count, fetch traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiering
from repro.core.tiering import TieredKVConfig, host_slot
from repro.models.api import ModelSpec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    served: int = 0  # CFS accounting
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    decoded_tokens: int = 0
    parks: int = 0  # coordinated context switches
    promoted_pages: int = 0
    evicted_pages: int = 0
    compactions: int = 0
    flushed_pages: int = 0
    flushed_tokens: int = 0

    @property
    def coalesce_ratio(self) -> float:
        """Tokens coalesced per flushed page-write (the paper's write-
        amplification win: 1 page write per page_size-token window instead
        of per token)."""
        return self.flushed_tokens / max(self.flushed_pages, 1)


class TieredEngine:
    def __init__(self, spec: ModelSpec, params, kv_cfg: TieredKVConfig,
                 use_pallas: bool = False):
        self.spec = spec
        self.cfg = spec.cfg
        self.kv = kv_cfg
        self.params = params
        self.state = tiering.init_state(kv_cfg, spec.cfg, dtype=jnp.bfloat16)
        self.step_fn = jax.jit(
            tiering.build_paged_decode_step(spec, kv_cfg, use_pallas=use_pallas)
        )
        self.requests: Dict[int, Request] = {}
        # host-side metadata
        self.hbm_owner: List[Optional[tuple]] = [None] * kv_cfg.n_hbm_pages
        self.lru: np.ndarray = np.zeros(kv_cfg.n_hbm_pages, np.int64)
        self.stats = ServeStats()
        self._clock = 0

    # ---- admission ----
    def add_request(self, req: Request) -> None:
        assert len(self.requests) < self.kv.max_requests, "slots exhausted"
        max_pages = -(-(len(req.prompt) + req.max_new_tokens) // self.kv.page_size)
        assert max_pages <= self.kv.n_hbm_pages, (
            f"request needs up to {max_pages} pages > HBM pool "
            f"{self.kv.n_hbm_pages}; enlarge the pool or page size"
        )
        assert max_pages <= self.kv.max_pages_per_req, "max_pages_per_req too small"
        rid = req.rid
        self.requests[rid] = req
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache = self.spec.prefill(self.params, prompt)
        k = cache["k"][:, 0]  # (L, S, KV, hd)
        v = cache["v"][:, 0]
        # initial placement: prompt KV lands in the HOST tier (the paper's
        # "all data starts in the CXL-SSD")
        self.state = tiering.write_prefill_pages(
            self.kv, self.state, rid, k, v
        )
        # the prompt's next token comes from the prefill logits
        req.out.append(int(jnp.argmax(logits[0])))
        req.served += 1
        self.stats.decoded_tokens += 1

    # ---- residency / promotion ----
    def _pages_needed(self, req: Request) -> List[int]:
        # attention reads pages only below the compaction watermark; newer
        # positions live in the (always-resident) write log
        compacted = int(self.state["compacted"][req.rid])
        n = (compacted + self.kv.page_size - 1) // self.kv.page_size
        return list(range(n))

    def _resident(self, rid: int, logical: int) -> bool:
        return int(self.state["page_table"][rid, logical]) >= 0

    def _free_slot(self, protect: set) -> Optional[int]:
        for s, owner in enumerate(self.hbm_owner):
            if owner is None:
                return s
        # LRU eviction among non-protected pages (clean by construction:
        # the log owns all un-flushed writes — the paper's key invariant)
        order = np.argsort(self.lru)
        for s in order:
            if self.hbm_owner[s] is not None and self.hbm_owner[s] not in protect:
                rid, logical = self.hbm_owner[s]
                self.state["page_table"] = self.state["page_table"].at[
                    rid, logical
                ].set(-1)
                self.hbm_owner[s] = None
                self.stats.evicted_pages += 1
                return int(s)
        return None

    def _promote(self, rid: int, logical: int, protect: set) -> bool:
        slot = self._free_slot(protect)
        if slot is None:
            return False
        pairs = jnp.asarray([[host_slot(self.kv, rid, logical), slot]], jnp.int32)
        self.state["hbm_k"], self.state["hbm_v"] = tiering.copy_pages(
            self.state["hbm_k"], self.state["hbm_v"],
            self.state["host_k"], self.state["host_v"], pairs,
        )
        self.state["page_table"] = self.state["page_table"].at[rid, logical].set(slot)
        self.hbm_owner[slot] = (rid, logical)
        self.lru[slot] = self._clock
        self.stats.promoted_pages += 1
        return True

    # ---- compaction ----
    def _compact(self) -> None:
        meta = np.asarray(self.state["log_meta"])
        dirty = {}
        for owner, pos in meta:
            if owner >= 0 and pos >= 0:
                dirty.setdefault((int(owner), int(pos) // self.kv.page_size), 0)
                dirty[(int(owner), int(pos) // self.kv.page_size)] += 1
        flush_hbm, flush_host = [], []
        for (rid, logical), ntok in sorted(dirty.items()):
            slot = int(self.state["page_table"][rid, logical])
            if slot >= 0:
                flush_hbm.append([rid, logical, slot])
            # ALWAYS flush to the host backing store (write-back tier);
            # resident copies are updated in parallel (paper: cache updated
            # alongside the log so flushes need no merge read)
            flush_host.append([rid, logical, host_slot(self.kv, rid, logical)])
            self.stats.flushed_pages += 1
            self.stats.flushed_tokens += ntok
        pad = [[-1, 0, -1]]
        fh = jnp.asarray((flush_hbm or pad), jnp.int32)
        fo = jnp.asarray((flush_host or pad), jnp.int32)
        self.state = tiering.compact_log(self.kv, self.state, fh, fo)
        self.stats.compactions += 1

    # ---- one engine step ----
    def step(self) -> None:
        self._clock += 1
        active = [r for r in self.requests.values() if not r.done]
        if not active:
            return
        # 0. compact BEFORE the residency check: compaction advances the
        # watermark, which can create page demand — readiness must be
        # evaluated against the post-compaction layout
        if int(self.state["log_tail"]) + self.kv.batch > self.kv.log_slots:
            self._compact()
        # 1. residency + parking (the coordinated context switch)
        ready, parked = [], []
        for r in active:
            missing = [p for p in self._pages_needed(r) if not self._resident(r.rid, p)]
            if missing:
                parked.append((r, missing))
            else:
                ready.append(r)
        # 2. promotion budget — closest-to-ready parked request first (SJF:
        # guarantees progress), just-promoted pages join the protect set so
        # the budget loop cannot evict its own work
        budget = self.kv.promote_pages_per_step
        protect = {(r.rid, p) for r in ready for p in self._pages_needed(r)}
        parked.sort(key=lambda rm: len(rm[1]))
        for r, missing in parked:
            self.stats.parks += 1
            for p in missing:
                if budget <= 0:
                    break
                if self._promote(r.rid, p, protect):
                    protect.add((r.rid, p))
                    budget -= 1
        # 3. schedule ready requests, least-served first (CFS)
        ready.sort(key=lambda r: r.served)
        batch = ready[: self.kv.batch]
        if not batch:
            return
        # 4. decode one token for the batch
        B = self.kv.batch
        req_ids = np.full((B,), -1, np.int32)
        tokens = np.zeros((B, 1), np.int32)
        for i, r in enumerate(batch):
            req_ids[i] = r.rid
            last = r.out[-1] if r.out else r.prompt[-1]
            tokens[i, 0] = last
        next_tok, self.state = self.step_fn(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(req_ids)
        )
        next_np = np.asarray(next_tok)
        for i, r in enumerate(batch):
            r.out.append(int(next_np[i, 0]))
            r.served += 1
            # touch LRU for this request's pages
            for p in self._pages_needed(r):
                s = int(self.state["page_table"][r.rid, p])
                if s >= 0:
                    self.lru[s] = self._clock
            if r.served >= r.max_new_tokens:
                r.done = True
            self.stats.decoded_tokens += 1
        self.stats.steps += 1

    def run(self, max_steps: int = 1000) -> ServeStats:
        for _ in range(max_steps):
            if all(r.done for r in self.requests.values()):
                break
            self.step()
        return self.stats
