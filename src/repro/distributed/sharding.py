"""Logical-axis -> mesh-axis sharding rules (MaxText-style, but tiny).

Parameters carry logical axis names in their schema (models/common.py).
This module translates them to PartitionSpecs for a concrete mesh, with a
divisibility check: a logical rule is dropped (replicated) when the dim is
not divisible by the mesh axis size — this is what makes one rule set work
across all 10 assigned archs (e.g. 40 q-heads do not divide a 16-wide model
axis; the flattened head dim usually does).

Default rules (2D: FSDP on "data" x TP/EP on "model"):
    vocab   -> model        embed -> data (FSDP)
    heads   -> model        kv    -> model
    ffn     -> model        inner -> model
    experts -> model (EP)   layers/None -> replicated
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common

Pytree = Any

DEFAULT_RULES: Dict[str, Any] = {
    "layers": None,
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "inner": "model",
    "experts": "model",
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for_leaf(leaf: common.Leaf, mesh: Mesh, rules=None) -> P:
    rules = rules or DEFAULT_RULES
    entries = []
    for dim, logical in zip(leaf.shape, leaf.axes):
        mesh_axis = rules.get(logical) if logical is not None else None
        if mesh_axis is not None and (
            mesh_axis not in mesh.shape or dim % _axis_size(mesh, mesh_axis) != 0
        ):
            mesh_axis = None  # divisibility fallback: replicate this dim
        entries.append(mesh_axis)
    return P(*entries)


def param_specs(schema: Pytree, mesh: Mesh, rules=None) -> Pytree:
    """PartitionSpec tree matching the schema tree."""
    return jax.tree_util.tree_map(
        lambda l: spec_for_leaf(l, mesh, rules), schema, is_leaf=common.is_leaf
    )


def param_shardings(schema: Pytree, mesh: Mesh, rules=None) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(schema, mesh, rules)
    )


def batch_spec(mesh: Mesh) -> P:
    """Global batch dim over every data-parallel axis present."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if axes else None)


def filter_spec_for_mesh(spec: P, mesh: Mesh, shape: Optional[Tuple[int, ...]] = None) -> P:
    """Drop axis names a mesh doesn't have (and non-divisible dims if shape
    given) from a PartitionSpec — lets one spec serve both mesh variants."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in mesh.shape)
        if shape is not None and names:
            size = int(np.prod([mesh.shape[n] for n in names]))
            if shape[i] % size != 0:
                names = ()
        out.append(names if len(names) > 1 else (names[0] if names else None))
    return P(*out)
