"""Checkpointing with async save, atomic publish, and elastic restore.

Fault-tolerance substrate for long runs:
  * save(step, state)    — tree flattened to npz + JSON manifest, written to
                           a temp dir and atomically renamed (a crash mid-
                           save never corrupts the latest checkpoint);
                           ``async_save`` moves serialization off the step
                           loop (overlap with compute).
  * restore(shardings=)  — loads the latest step; when ``shardings`` is
                           given, every leaf is re-placed with the NEW
                           sharding — restoring onto a different mesh/
                           device count (elastic scaling) is therefore the
                           same code path as same-mesh restart.
  * keeps the data-pipeline state in the manifest so input streams resume
    exactly.

At 1000+-node scale each host would write only its addressable shards
(jax.experimental.array_serialization); the manifest/atomic-rename/elastic
structure here is the same — documented in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ---- save ----
    def save(self, step: int, state: Pytree, extra: Optional[Dict] = None) -> None:
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, str(treedef), extra)
            )
            self._thread.start()
        else:
            self._write(step, host_leaves, str(treedef), extra)

    def _write(self, step, host_leaves, treedef_str, extra) -> None:
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz has no bfloat16: store a uint16 view, record the true dtype
        dtypes = [str(a.dtype) for a in host_leaves]
        stored = [
            a.view(np.uint16) if str(a.dtype) == "bfloat16" else a
            for a in host_leaves
        ]
        np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(stored)})
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "dtypes": dtypes,
            "treedef": treedef_str,
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---- restore ----
    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        ]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(
        self,
        target: Pytree,
        step: Optional[int] = None,
        shardings: Optional[Pytree] = None,
    ):
        """Restore into the structure of ``target``. ``shardings`` (a tree
        matching target, or a single sharding) re-places leaves — pass the
        NEW mesh's shardings to restore elastically."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "leaves.npz") as z:
            host_leaves = [z[f"l{i}"] for i in range(manifest["n_leaves"])]
        import ml_dtypes

        host_leaves = [
            a.view(ml_dtypes.bfloat16) if dt == "bfloat16" else a
            for a, dt in zip(host_leaves, manifest.get("dtypes", [""] * len(host_leaves)))
        ]
        leaves, treedef = jax.tree_util.tree_flatten(target)
        assert len(leaves) == len(host_leaves), (
            f"checkpoint has {len(host_leaves)} leaves, target {len(leaves)}"
        )
        if shardings is None:
            new = [jax.numpy.asarray(a) for a in host_leaves]
        else:
            sh_leaves = (
                jax.tree_util.tree_leaves(shardings)
                if not isinstance(shardings, jax.sharding.Sharding)
                else [shardings] * len(host_leaves)
            )
            new = [
                jax.device_put(a, s) for a, s in zip(host_leaves, sh_leaves)
            ]
        return jax.tree_util.tree_unflatten(treedef, new), manifest["extra"], step
