"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024(expert)
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50_304,
        qk_norm=True,  # OLMoE uses QK-norm
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
        rope_theta=10_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 4},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=128,
        qk_norm=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96),
        microbatch={"train_4k": 2},
    )
