"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.

Encoder-decoder; conv audio frontend is a STUB — input_specs() supplies
precomputed frame embeddings of length seq_len // 4 (Whisper's conv stack
downsamples 2x over 2x-strided mel frames). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,  # decoder depth
        enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51_865,
        frontend="audio",
        rope_theta=10_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 16},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-reduced",
        family="encdec",
        n_layers=2,
        enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        frontend="audio",
        microbatch={"train_4k": 2},
    )
