"""Config system for the repro framework.

Three config families:
  * ModelConfig  — architecture hyperparameters (one file per assigned arch).
  * ShapeConfig  — the assigned input-shape grid (train_4k / prefill_32k /
                   decode_32k / long_500k).
  * SimConfig    — the SkyByte CXL-SSD simulator parameters (paper Table II).

Everything is a frozen dataclass so configs are hashable and safe to close
over in jitted functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block parameters."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence block parameters (RWKV6, Mamba2)."""

    kind: str  # "rwkv6" | "mamba2"
    heads: int
    head_dim: int
    state_dim: int  # per-head recurrent state width
    chunk: int = 128  # chunked-scan block length (sequence dim)
    conv_dim: int = 4  # mamba2 short conv width
    expand: int = 2  # mamba2 inner expansion


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture. Families:

    dense  — decoder-only transformer (GQA)
    moe    — decoder-only transformer with MoE FFN
    ssm    — attention-free (RWKV6)
    hybrid — Mamba2 backbone + shared attention block (Zamba2)
    encdec — encoder-decoder transformer (Whisper), audio frontend stubbed
    vlm    — decoder-only backbone + vision patch frontend stubbed (LLaVA)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper): n_layers is the decoder depth; enc_layers the encoder.
    enc_layers: int = 0
    # hybrid (zamba2): apply the single shared attention block every N layers.
    shared_attn_every: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    # number of stub frontend embeddings prepended to the token sequence
    # (vision patches). For "audio" the encoder length is seq_len // 4.
    n_frontend_tokens: int = 0
    dtype: str = "bfloat16"
    # True if sequence mixing is sub-quadratic (eligible for long_500k).
    sub_quadratic: bool = False
    # per-(shape-name) microbatch size PER DATA SHARD for gradient
    # accumulation; keys missing -> default 8.
    microbatch: Mapping[str, int] = field(default_factory=dict)
    # serving: tokens per KV page for the SkyByte paged-KV runtime.
    kv_page_size: int = 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, h, kv, hd, ff, L = (
            self.d_model,
            self.n_heads,
            self.n_kv_heads,
            self.resolved_head_dim,
            self.d_ff,
            self.n_layers,
        )
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d  # lm head
        if self.family in ("dense", "moe", "vlm", "encdec"):
            attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if self.family == "moe" and self.moe is not None:
                ffn = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                if self.moe.shared_expert:
                    ffn += 3 * d * (self.moe.d_ff_shared or ff)
            else:
                ffn = 3 * d * ff
            n += L * (attn + ffn + 2 * d)
            if self.family == "encdec":
                # encoder blocks + decoder cross-attention
                n += self.enc_layers * (attn + 3 * d * ff + 2 * d)
                n += L * (attn + d)  # cross attn + its norm
        elif self.family == "ssm":
            s = self.ssm
            inner = s.heads * s.head_dim
            # rwkv6: time-mix (r,k,v,g,o + decay/first) + channel-mix
            n += L * (5 * d * inner + 2 * inner + 3 * d * ff // 2 + 2 * d)
        elif self.family == "hybrid":
            s = self.ssm
            inner = self.d_model * s.expand
            mamba = d * 2 * inner + inner * s.conv_dim + inner * (
                2 * s.state_dim
            ) + inner * d + 2 * s.heads
            n += L * (mamba + 2 * d)
            attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            n += attn + 3 * d * ff + 2 * d  # one shared block
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        total = self.param_count()
        all_experts = L * m.num_experts * 3 * d * m.d_ff_expert
        active = L * m.top_k * 3 * d * m.d_ff_expert
        return total - all_experts + active


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell. kind selects which step is lowered:
    train -> train_step, prefill -> prefill, decode -> serve_step."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per DESIGN.md §Shape skips."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return True, ""


# ---------------------------------------------------------------------------
# Training / runtime configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # distributed-optimization tricks
    compress_grads: bool = False  # int8 + error-feedback DP all-reduce


@dataclass(frozen=True)
class RunConfig:
    """Launcher-level knobs."""

    arch: str = "smollm-135m"
    shape: str = "train_4k"
    multi_pod: bool = False
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    seed: int = 0
    tiering: str = "skybyte"  # "skybyte" | "baseline" (serving KV management)
    # activation (sequence) sharding of the residual stream over the model
    # axis between layers — beyond-paper memory optimization (see §Perf).
    seq_shard_activations: bool = False
    remat: str = "full"  # "full" | "none"


# ---------------------------------------------------------------------------
# SkyByte simulator config — paper Table II
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlashTiming:
    """NAND flash timing (paper Table IV), in nanoseconds."""

    read_ns: float = 3_000.0  # ULL Z-NAND tR
    program_ns: float = 100_000.0  # tProg
    erase_ns: float = 1_000_000.0  # tBERS


FLASH_CLASSES: Mapping[str, FlashTiming] = {
    "ULL": FlashTiming(3_000.0, 100_000.0, 1_000_000.0),  # Samsung Z-NAND
    "ULL2": FlashTiming(4_000.0, 75_000.0, 850_000.0),  # Toshiba XL-Flash
    "SLC": FlashTiming(25_000.0, 200_000.0, 1_500_000.0),
    "MLC": FlashTiming(50_000.0, 600_000.0, 3_000_000.0),
}


@dataclass(frozen=True)
class FaultConfig:
    """Device fault-injection knobs (core/faults.py). All draws come from a
    counter-based hash stream keyed by ``fault_seed`` and the device's
    flash-read ordinal, so a cell's fault sequence is a pure function of
    the config — both replay engines consume the identical stream and stay
    bit-exact (see DESIGN.md "Fault model & crash recovery").

    Every knob defaults to OFF (rate 0.0 / empty schedule): the zero-fault
    config constructs no FaultModel at all, so the flawless-device figures
    and their cache keys are unchanged and the hot path pays one
    ``is not None`` test per flash read."""

    # Per-read probability that the first ECC sense fails and the retry
    # ladder engages. Real raw BERs sit around 1e-4..1e-2 *per bit*; here
    # the rate is per PAGE READ because the simulator's unit of work is a
    # page, so sweep values (fig_faults: 1e-3..3e-2) model end-of-life
    # pages where a first-sense failure is a per-read-scale event.
    read_error_rate: float = 0.0
    # Geometric ladder: step k is reached with probability
    # read_error_rate * retry_fail_ratio**k. 0.25 means each extra
    # read-retry voltage shift recovers 3 of 4 remaining failures —
    # the shape (most retries resolve in 1-2 steps, a thin tail walks the
    # whole ladder) matches published read-retry distributions.
    retry_fail_ratio: float = 0.25
    # Ladder depth before the read is declared uncorrectable (counted in
    # Stats.uncorrectable_reads / uber; the read still completes at
    # max-ladder latency — the device returns poison, not a hang).
    retry_steps: int = 4
    # Latency each ladder step adds to the die's sense time. 0.0 (the
    # default) means "one full re-sense", i.e. flash.read_ns — retries on
    # real NAND re-issue the array read at a shifted reference voltage.
    retry_step_ns: float = 0.0
    # Transient die/channel outage: per-read probability that the target
    # die is unavailable (firmware busy, channel CRC storm) and service
    # starts late by outage_ns. 500us sits between a program (100us) and
    # an erase (1ms): long enough to be a visible tail event.
    outage_rate: float = 0.0
    outage_ns: float = 500_000.0
    # Whole-die hard failures: at each listed flash-read ordinal, the die
    # that read targeted fails permanently — its blocks go bad, valid
    # pages remap through the free pool (block backend only).
    die_fail_at: Tuple[int, ...] = ()
    # Scheduled power-loss events, again in flash-read ordinals (a
    # deterministic virtual-time-free trigger both engines hit at the
    # same instant). On each: in-flight programs and the volatile page
    # cache are lost; the cacheline write log is durable (the paper's
    # §III-B persistence claim) and is replayed against the FTL.
    power_loss_at: Tuple[int, ...] = ()
    # Fixed firmware restart cost added on top of replay time (FTL table
    # scan, CXL link retrain) before the device serves again.
    recovery_scan_ns: float = 1_000_000.0
    # Seed for the fault draw stream, independent of the workload seed so
    # fault placement can be varied against a fixed trace.
    fault_seed: int = 0

    @property
    def enabled(self) -> bool:
        return (self.read_error_rate > 0.0 or self.outage_rate > 0.0
                or bool(self.die_fail_at) or bool(self.power_loss_at))


@dataclass(frozen=True)
class ObsConfig:
    """Latency-provenance observability knobs (core/obs.py).

    Off by default: the zero-obs config attaches no ObsModel anywhere, so
    the hot paths pay one ``is not None`` test and the fused engine stays
    eligible. With ``enabled=True`` every host-visible completion is
    decomposed into additive latency components (conservation-checked to
    sum bit-exactly to the engine's latency), per-component log-scale
    histograms and exact percentiles are kept, a time-window interval
    ring records storm timelines, and a bounded event ring feeds
    ``scripts/trace_export.py`` (Chrome/Perfetto trace-event JSON).
    Obs-active cells are a conflict class like faults/QoS: ``run_fused``
    refuses them and both engines route flash reads through the one
    attribution site (``Channels.read`` / ``QosModel.read`` /
    ``FaultModel.read``). Unlike faults-vs-QoS, obs COMPOSES with either."""

    enabled: bool = False
    # Interval-metric window width. Windows start at t=0; when a run
    # outgrows max_windows the width doubles and adjacent windows fold
    # (deterministic in event order, so both engines agree bit-for-bit).
    window_ns: float = 1_000_000.0
    max_windows: int = 256
    # Bounded event ring (GC windows, suspends, retries, outages,
    # recovery barriers, bus convoys, compaction drains): oldest events
    # are dropped beyond the cap.
    max_events: int = 8192
    # Slowest-K retired requests kept with their full component vectors
    # (exported as Perfetto flow events).
    slow_k: int = 32
    # A read whose channel-bus wait exceeds this is recorded as a
    # "convoy" event (4 back-to-back 800ns transfers by default).
    convoy_ns: float = 3_200.0


@dataclass(frozen=True)
class SimConfig:
    """CXL-SSD simulator parameters. Defaults follow paper Table II scaled by
    `scale` so laptop-scale runs finish quickly (the paper itself scales the
    2TB/16GB Samsung prototype down to 128GB/512MB at the same ratio; we keep
    all *ratios* fixed and scale absolute sizes by `scale`)."""

    # --- host CPU ---
    n_cores: int = 8
    n_threads: int = 8  # 24 when context switch is enabled (paper §VI-A)
    freq_ghz: float = 4.0
    # OoO overlap window: short latencies (SSD DRAM hits) are partially hidden
    # behind the compute gap; models 256-entry ROB MLP at request level.
    overlap_ns: float = 60.0
    max_outstanding: int = 8  # per-core MSHR-limited outstanding misses
    # --- host DRAM ---
    host_dram_ns: float = 70.0
    # max bytes of promoted pages in host DRAM (Table II: 2GB at scale=1)
    host_dram_bytes: int = 2 << 30
    # --- CXL / SSD ---
    cxl_protocol_ns: float = 40.0
    ssd_dram_ns: float = 120.0  # LPDDR4 access
    log_index_ns: float = 72.0  # §V FPGA measurement: write-log index lookup
    cache_index_ns: float = 49.0  # §V: data-cache index lookup
    page_bytes: int = 4_096
    cacheline_bytes: int = 64
    # SSD geometry (Table II): 16 channels, 128GB total at scale=1
    n_channels: int = 16
    flash_bytes: int = 128 << 30
    ssd_dram_bytes: int = 512 << 20  # data cache + write log budget
    write_log_bytes: int = 64 << 20
    channel_queue_depth: int = 64
    flash: FlashTiming = field(default_factory=FlashTiming)
    # --- GC (Table II) ---
    gc_threshold: float = 0.80  # trigger when utilization above this
    gc_pages_per_event: int = 256  # valid pages migrated per GC event
    # --- block-granular flash backend (core/flash.py) ---
    # "block": erase-block FTL with log-structured page mapping, dense
    #   valid bitmaps, victim-policy GC whose cost is proportional to the
    #   victim's live pages, and wear/WAF accounting (the default). Every
    #   read and program resolves its channel/die from the PHYSICAL
    #   location the FTL chose (block-id-derived; see flash.blk_loc), so
    #   GC storms, wear leveling and hot/cold placement are visible in
    #   service latency, not only in WAF side-channels.
    # "legacy": the free-page counter with fixed 8-page GC cost and the
    #   original logical page-hash striping (Channels.logical_loc) —
    #   bit-exact PR 4 routing, kept as the regression anchor.
    ftl_backend: str = "block"
    pages_per_block: int = 64  # erase-block size in (4KB) pages
    # Physical over-provisioning: phys pages = logical * (1 + op_ratio).
    # The default is deliberately at the low end: scale=128 shrinks every
    # footprint ~two orders of magnitude but benchmark windows shrink
    # with it, so a datacenter-class OP fraction would never exhaust the
    # spare pool inside a run — 3% keeps GC live on every Table I
    # workload at the fig18 request counts (benchmarks/fig_gc_tail.py
    # sweeps this knob upward).
    op_ratio: float = 0.03
    gc_policy: str = "greedy"  # "greedy" | "cost-benefit"
    # Wear-aware free-block allocation: sealed frontiers draw their
    # replacement from the free pool by LOWEST erase count (block-id
    # tie-break) instead of LIFO pop. LIFO recycles the handful of
    # recently-erased blocks back-to-back, so a rewrite-heavy working set
    # concentrates erases on a few blocks (wear_max_erases >> mean);
    # lowest-erase picks rotate the whole spare pool and flatten the
    # spread (fig_gc_tail's wear rows sweep this knob). Off by default:
    # the LIFO pick is the PR 4 behaviour and keeps the headline grid's
    # placement anchored.
    wear_leveling: bool = False
    # Hot/cold write frontiers: host programs split across TWO open host
    # frontier blocks by rewrite heat — a program is "hot" (lands on the
    # hot frontier) when its previous physical copy still sits in an OPEN
    # block OR in one sealed within the last heat_win seal ticks
    # (heat_win = max(8, data_blocks/4), flash.FlashState: the page's
    # rewrite interval is short relative to the data set — eviction- and
    # compaction-driven rewrite intervals span many blocks, so an
    # open-block-only test would classify nearly everything cold).
    # Everything else goes cold. Hot pages die together, so hot blocks
    # seal near-fully-invalid (cheap GC victims) while cold blocks stay
    # valid and untouched — the classic greedy-cleaning hot/cold
    # separation, now observable end-to-end because reads route to the
    # physical die the frontier chose. Off by default (single host
    # frontier, PR 4 layout).
    hotcold: bool = False
    # --- context switch (paper §III-A) ---
    ctx_switch_ns: float = 2_000.0
    ctx_threshold_ns: float = 2_000.0
    sched_policy: str = "CFS"  # "RR" | "RANDOM" | "CFS"
    # --- design-point flags (paper §VI-A ablation grid) ---
    enable_ctx_switch: bool = False  # -C
    enable_promotion: bool = False  # -P
    enable_write_log: bool = False  # -W
    dram_only: bool = False  # ideal DRAM-Only baseline
    # --- promotion policy (§III-C / §VI-H alternatives) ---
    # "skybyte": per-page counters + threshold (the paper's default)
    # "tpp": TPP-style periodic sampling (noisier hotness estimate)
    # "astriflash": host DRAM as a page-granular cache of every SSD access
    promo_policy: str = "skybyte"
    promote_threshold: int = 8  # accesses before a page becomes a candidate
    migration_page_ns: float = 3_000.0  # page copy + PLB bookkeeping
    # --- simulation scale ---
    scale: int = 128  # divide all capacities by this (ratios preserved)
    cache_ways: int = 8
    # --- replay engine ---
    # Both engines operate on one authoritative DeviceState
    # (core/device_state.py); there are no engine-private state mirrors.
    # "batched": vectorized fast path (core/engine.py), statistically
    #   bit-compatible with the reference loop; every state-changing
    #   boundary is transcribed in the engine itself.
    # "reference": the original per-event Python loop (ground truth;
    #   Machine.serve() survives as its parity oracle).
    # "turbo": opt-in fast-math engine (core/turbo.py). Every discrete
    #   decision (scheduling, classification, GC, FTL mapping, park/
    #   promote/compact) stays bit-exact with the other two engines; only
    #   the four float timeline chains are reassociated — per-event
    #   `t += gap; t += lat` scalar adds become one gap prefix-sum per
    #   thread plus count*constant folds per boundary. Timing outputs
    #   (AMAT, exec_ns, percentiles) may drift within turbo_rtol.
    engine: str = "batched"
    # Upper bound the turbo engine accepts on its own accumulated
    # relative timing error (engine="turbo" only; see turbo_drift_* in
    # Stats). The engine tracks an a-priori reassociation bound — ulps
    # per time re-anchor plus the gap prefix-sum's n*eps term — and
    # raises if the bound exceeds this knob, so a run can never silently
    # report timings looser than the configured contract. Default 1e-9
    # sits ~3 decades above the measured ~1e-12 drift and ~3 below the
    # 1e-6 the parity tests assert against the reference engine.
    turbo_rtol: float = 1e-9
    # Cross-quantum classification cache (batched engine only; see
    # core/engine.py). Classification work persists across scheduling
    # quanta and is repaired through per-page epoch counters instead of
    # being recomputed per quantum — the win on context-switch-bound
    # cells whose quanta sit far below the NumPy break-even.
    cls_cache: bool = True
    # Minimum fast-run-length EWMA to run the cached vector path; below it
    # boundary-density makes per-event inline replay cheaper than
    # per-boundary cache repair. Since the unified-DeviceState refactor the
    # inline span executes misses/evictions/GC over the shared arrays with
    # no per-event dispatch, which moved the measured break-even from ~20
    # events up to the no-cache vectorization threshold (~192): NumPy
    # dispatch on boundary-sized chunks costs more than the span's
    # per-event loop for anything shorter.
    cls_cache_min_run: float = 192.0
    # Cap on the classified-range length (events) a thread caches ahead;
    # the range otherwise scales with the engine's adaptive chunk.
    cls_cache_window: int = 65536
    # --- fault injection & recovery (core/faults.py) ---
    # Default FaultConfig() is fully off; any nonzero knob attaches a
    # FaultModel to Channels.read and routes the batched engine through
    # the scalar span/quantum paths (fault-affected reads are a conflict
    # class — see DESIGN.md). Knob-by-knob rationale lives on FaultConfig.
    fault: FaultConfig = field(default_factory=FaultConfig)
    # --- latency provenance / observability (core/obs.py) ---
    # Default ObsConfig() is fully off. obs.enabled=True attaches an
    # ObsModel (additive latency-component accounting + interval ring +
    # event recorder) and routes the batched engine off the fused path
    # (obs-active cells are a conflict class — see DESIGN.md "Latency
    # provenance"). Composes with either faults or QoS.
    obs: ObsConfig = field(default_factory=ObsConfig)
    # --- die-level QoS (core/qos.py; DESIGN.md "Die-level QoS") ---
    # GC suspend/resume: a host read that lands inside a carved
    # [gc_die_from, gc_die_until] window preempts the GC chain instead of
    # waiting it out — the read pays gc_suspend_ns (bounded, ~erase-slice
    # granularity) rather than the window's full residual, and the
    # suspended GC work resumes behind the read with a fixed
    # gc_resume_ns re-setup penalty. Off by default: QoS-active reads
    # are a conflict class (both engines route through one QosModel.read,
    # like faults), so the fused fast path is reserved for zero-QoS cells.
    gc_suspend: bool = False
    # Preemption latency: how long the in-flight erase/program slice takes
    # to reach a suspendable point. 5us ~ one NAND suspend command on
    # datasheet-class parts (tens of us worst case); it is the floor a
    # suspended-GC read still pays, so it bounds the QoS'd read tail.
    gc_suspend_ns: float = 5_000.0
    # Resume re-setup cost charged to the DIE (not the read) per suspend:
    # re-ramping the erase voltage / re-issuing the program costs real
    # time, which is exactly why suspends must be bounded — each one
    # stretches the GC window by read_ns + gc_resume_ns.
    gc_resume_ns: float = 20_000.0
    # Suspends allowed per carved GC window (refilled when a die starts a
    # new window). Caps worst-case GC stretch at
    # gc_suspend_max * (read_ns + gc_resume_ns) so a read storm cannot
    # starve cleaning and collapse the free pool. 0 = never suspend even
    # with gc_suspend=True (useful for the bounded-count tests).
    gc_suspend_max: int = 4
    # Read-priority die arbitration: outside GC windows, a read that would
    # queue behind more than read_priority_wait_ns of die backlog (host
    # and GC programs) is scheduled ahead of the queued work instead —
    # the in-flight op cannot be preempted, so the read still waits up to
    # the cap, and the displaced programs are pushed back by the read's
    # die occupancy. Complements gc_suspend: suspend shrinks GC convoys,
    # read priority shrinks program convoys.
    read_priority: bool = False
    # Backlog threshold above which a read bypasses the die queue. One
    # program time (100us) by default: an arbiter can reorder the QUEUE
    # but not the die, so one in-flight program is the irreducible wait.
    # (read_priority also arms the channel-bus queue-jump — QosModel._xfer
    # — which needs no knob: its cap is structurally one in-flight 800ns
    # transfer, and bus convoys behind write bursts are frequently the
    # dominant read wait.)
    read_priority_wait_ns: float = 100_000.0
    # Superblock striped-frontier placement: stripe each logical block's
    # pages page-by-page across channels then dies (page p of block b
    # lives on channel (b*ppb+p) % n_channels) instead of placing whole
    # blocks on one die. Sequential reads fan across all channels, but a
    # GC victim's blast radius grows from ONE die to every die the stripe
    # touches — fig_gc_tail's qos sweep quantifies that trade. Placement
    # only: mappings change, arbitration does not, so superblock alone
    # keeps the fused engine.
    superblock: bool = False

    def __post_init__(self) -> None:
        # Reject incoherent QoS knob combos loudly (PR 4 style): every
        # message names the knob so a sweep script can diagnose itself.
        if self.superblock and self.ftl_backend != "block":
            raise ValueError(
                "superblock=True stripes the block FTL's frontier and "
                f"requires ftl_backend='block' (got {self.ftl_backend!r}); "
                "the legacy backend has no physical blocks to stripe"
            )
        if self.gc_suspend_max < 0:
            raise ValueError(
                f"gc_suspend_max must be >= 0 (got {self.gc_suspend_max}); "
                "use 0 to disable suspension, not a negative sentinel"
            )
        if self.gc_suspend_ns < 0.0 or self.gc_resume_ns < 0.0:
            raise ValueError(
                "gc_suspend_ns and gc_resume_ns are latencies and must be "
                f">= 0 (got {self.gc_suspend_ns}, {self.gc_resume_ns})"
            )
        if self.read_priority_wait_ns <= 0.0:
            raise ValueError(
                "read_priority_wait_ns must be > 0 (got "
                f"{self.read_priority_wait_ns}); the in-flight die op "
                "cannot be preempted, so a zero wait cap is unsatisfiable"
            )
        if self.fault.enabled and (
            self.gc_suspend or self.read_priority or self.superblock
        ):
            raise ValueError(
                "fault injection cannot be combined with QoS knobs "
                "(gc_suspend/read_priority/superblock): FaultModel.read "
                "and die-failure remap assume per-die blocks and the "
                "un-arbitrated timing recipe"
            )
        if self.turbo_rtol <= 0.0:
            raise ValueError(
                f"turbo_rtol must be > 0 (got {self.turbo_rtol}); the turbo "
                "engine's drift bound is strictly positive on any nonempty "
                "run — use engine='batched' for bit-exact timelines"
            )
        if self.obs.enabled:
            if self.obs.window_ns <= 0.0:
                raise ValueError(
                    f"obs.window_ns must be > 0 (got {self.obs.window_ns}); "
                    "the interval ring indexes windows as t // window_ns"
                )
            if self.obs.max_windows < 2 or self.obs.max_windows % 2:
                raise ValueError(
                    f"obs.max_windows must be an even count >= 2 (got "
                    f"{self.obs.max_windows}); overflow folds windows "
                    "pairwise into half the ring at double the width"
                )
            if self.obs.max_events < 0 or self.obs.slow_k < 0:
                raise ValueError(
                    "obs.max_events and obs.slow_k are ring capacities and "
                    f"must be >= 0 (got {self.obs.max_events}, "
                    f"{self.obs.slow_k})"
                )

    # ----- derived (scaled) quantities -----
    @property
    def eff_flash_bytes(self) -> int:
        return self.flash_bytes // self.scale

    @property
    def eff_ssd_dram_bytes(self) -> int:
        return self.ssd_dram_bytes // self.scale

    @property
    def eff_write_log_bytes(self) -> int:
        return self.write_log_bytes // self.scale

    @property
    def eff_host_dram_bytes(self) -> int:
        return self.host_dram_bytes // self.scale

    @property
    def n_flash_pages(self) -> int:
        return self.eff_flash_bytes // self.page_bytes

    @property
    def log_entries(self) -> int:
        return self.eff_write_log_bytes // self.cacheline_bytes

    @property
    def cache_pages(self) -> int:
        if self.enable_write_log:
            return (self.eff_ssd_dram_bytes - self.eff_write_log_bytes) // self.page_bytes
        return self.eff_ssd_dram_bytes // self.page_bytes

    @property
    def host_pages(self) -> int:
        return self.eff_host_dram_bytes // self.page_bytes

    @property
    def lines_per_page(self) -> int:
        return self.page_bytes // self.cacheline_bytes

    @property
    def qos_enabled(self) -> bool:
        """True when a QosModel must arbitrate reads (conflict class).

        superblock alone is deliberately NOT included: it changes
        placement, not arbitration, so striped zero-QoS cells keep the
        fused engine."""
        return self.gc_suspend or self.read_priority

    def variant(self, name: str) -> "SimConfig":
        """Paper §VI-A design points by name."""
        flags = {
            "base-cssd": dict(),
            "skybyte-c": dict(enable_ctx_switch=True),
            "skybyte-p": dict(enable_promotion=True),
            "skybyte-w": dict(enable_write_log=True),
            "skybyte-cp": dict(enable_ctx_switch=True, enable_promotion=True),
            "skybyte-wp": dict(enable_write_log=True, enable_promotion=True),
            "skybyte-full": dict(
                enable_ctx_switch=True,
                enable_promotion=True,
                enable_write_log=True,
            ),
            "dram-only": dict(dram_only=True),
        }[name.lower()]
        n_threads = self.n_cores * 3 if flags.get("enable_ctx_switch") else self.n_cores
        return dataclasses.replace(self, n_threads=n_threads, **flags)


VARIANTS = (
    "base-cssd",
    "skybyte-c",
    "skybyte-p",
    "skybyte-w",
    "skybyte-cp",
    "skybyte-wp",
    "skybyte-full",
    "dram-only",
)
