"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12_288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        vocab=32_768,
        rope_theta=1_000_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 1},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-reduced",
        family="dense",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=224,
        vocab=128,
        microbatch={"train_4k": 2},
    )
