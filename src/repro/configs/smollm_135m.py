"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

Also the end-to-end training example arch (examples/train_lm.py).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49_152,
        tie_embeddings=True,
        rope_theta=10_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 8},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-reduced",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=128,
        vocab=128,
        tie_embeddings=True,
        microbatch={"train_4k": 2},
    )
