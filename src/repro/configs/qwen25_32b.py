"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27_648,
        vocab=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 2},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=128,
        qkv_bias=True,
        microbatch={"train_4k": 2},
    )
