"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch: data-dependent decay linear recurrence. [arXiv:2404.05892; hf]

Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # head_dim 64
        n_kv_heads=40,
        d_ff=8960,
        vocab=65_536,
        ssm=SSMConfig(kind="rwkv6", heads=40, head_dim=64, state_dim=64, chunk=64),
        sub_quadratic=True,
        microbatch={"train_4k": 4},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-reduced",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        ssm=SSMConfig(kind="rwkv6", heads=4, head_dim=16, state_dim=16, chunk=32),
        sub_quadratic=True,
        microbatch={"train_4k": 2},
    )
