"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; vision frontend is a STUB (input_specs()
supplies precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20_480,
        vocab=64_000,
        frontend="vision",
        n_frontend_tokens=1152,  # anyres: base 576 + 576 tile patches (2x2 pooled)
        rope_theta=5_000_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 1},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-reduced",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=128,
        frontend="vision",
        n_frontend_tokens=16,
        microbatch={"train_4k": 2},
    )
