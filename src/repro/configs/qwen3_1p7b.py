"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 4},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab=128,
        qk_norm=True,
        microbatch={"train_4k": 2},
    )
