"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention block applied every 6
layers (Zamba2's per-invocation LoRA on the shared block is simplified to
fully-shared weights; noted in DESIGN.md). [arXiv:2411.15242; unverified]

Hybrid (Mamba2 + periodic attention): runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14_336,
        vocab=32_000,
        ssm=SSMConfig(kind="mamba2", heads=56, head_dim=128, state_dim=64, chunk=128),
        shared_attn_every=6,
        rope_theta=10_000.0,
        sub_quadratic=True,
        microbatch={"train_4k": 2},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=128,
        ssm=SSMConfig(kind="mamba2", heads=4, head_dim=32, state_dim=16, chunk=32),
        shared_attn_every=2,
        sub_quadratic=True,
        microbatch={"train_4k": 2},
    )
