"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert; early-fusion multimodal
noted in DESIGN.md (text backbone built here).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202_048,
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            d_ff_expert=8192,
            shared_expert=True,
            d_ff_shared=8192,
        ),
        rope_theta=500_000.0,
        sub_quadratic=False,
        microbatch={"train_4k": 1},
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=128,
        moe=MoEConfig(
            num_experts=4, top_k=1, d_ff_expert=96, shared_expert=True, d_ff_shared=96
        ),
        microbatch={"train_4k": 2},
    )
