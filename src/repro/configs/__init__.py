"""Architecture registry: --arch <id> resolution for every assigned arch."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs.base import (
    FLASH_CLASSES,
    FlashTiming,
    ModelConfig,
    MoEConfig,
    OptimConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    SimConfig,
    SSMConfig,
    VARIANTS,
    shape_applicable,
)
from repro.configs import (
    llama4_scout,
    llava_next_34b,
    mistral_large_123b,
    olmoe_1b_7b,
    qwen25_32b,
    qwen3_1p7b,
    rwkv6_3b,
    smollm_135m,
    whisper_base,
    zamba2_7b,
)

_MODULES = {
    "whisper-base": whisper_base,
    "qwen2.5-32b": qwen25_32b,
    "mistral-large-123b": mistral_large_123b,
    "smollm-135m": smollm_135m,
    "qwen3-1.7b": qwen3_1p7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "llama4-scout-17b-a16e": llama4_scout,
    "rwkv6-3b": rwkv6_3b,
    "llava-next-34b": llava_next_34b,
    "zamba2-7b": zamba2_7b,
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].config()


def get_reduced(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.config() for k, m in _MODULES.items()}


__all__ = [
    "ARCH_IDS",
    "FLASH_CLASSES",
    "FlashTiming",
    "ModelConfig",
    "MoEConfig",
    "OptimConfig",
    "RunConfig",
    "SHAPES",
    "ShapeConfig",
    "SimConfig",
    "SSMConfig",
    "VARIANTS",
    "all_configs",
    "get_config",
    "get_reduced",
    "shape_applicable",
]
