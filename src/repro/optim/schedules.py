"""LR schedules (pure functions of a traced step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimConfig


def cosine_schedule(cfg: OptimConfig, step) -> jnp.ndarray:
    t = step.astype(jnp.float32)
    warm = cfg.lr * t / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (t - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(t < cfg.warmup_steps, warm, cos)
