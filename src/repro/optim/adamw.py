"""Sharded AdamW with fp32 master weights.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the parameter
PartitionSpecs apply verbatim to ``mu``/``nu``/``master`` — the ZeRO-style
sharding comes for free from the 2D (FSDP x TP) parameter layout.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    mu: Pytree  # fp32, like params
    nu: Pytree  # fp32, like params
    master: Pytree  # fp32 master copy of params


def adamw_init(params: Pytree) -> AdamWState:
    # mu/nu must be distinct buffers (donation forbids aliased arguments)
    zeros = lambda: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params
    )
    master = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(), zeros(), master)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads)
    )
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(
    cfg: OptimConfig, state: AdamWState, grads: Pytree, lr: jax.Array
) -> Tuple[Pytree, AdamWState, jax.Array]:
    """Returns (new bf16 params, new state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, mu, nu, master):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        master = master - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * master)
        return mu, nu, master

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    params = jax.tree_util.tree_map(lambda m: m.astype(jnp.bfloat16), master)
    return params, AdamWState(step, mu, nu, master), gnorm
