from repro.optim.adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from repro.optim.grad_compress import compress_decompress, error_feedback_update  # noqa: F401
from repro.optim.schedules import cosine_schedule  # noqa: F401
