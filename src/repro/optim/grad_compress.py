"""int8 gradient compression with error feedback — the write-log idea on the
optimizer path (DESIGN.md §2 Layer B): quantization error is *logged* into a
residual buffer and coalesced into later updates instead of being flushed
(lost) every step, exactly the coalesce-before-writeback structure of the
paper's SSD write log.

Used on the DP all-reduce: grads are quantized to int8 per-tensor-scale
before the reduction, halving (vs bf16) or quartering (vs fp32) collective
bytes; error feedback keeps convergence unaffected to first order.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Round-trip through int8. Returns (g_hat, error)."""
    g32 = g.astype(jnp.float32)
    q, s = quantize_int8(g32)
    g_hat = dequantize_int8(q, s)
    return g_hat, g32 - g_hat


def error_feedback_update(grads: Pytree, residual: Pytree) -> Tuple[Pytree, Pytree]:
    """Apply error feedback: compress (grad + residual), carry new residual.

    The returned compressed grads are what the DP all-reduce sees; the
    residual tree is carried in the train state (sharded like params).
    """

    def one(g, r):
        g_hat, err = compress_decompress(g.astype(jnp.float32) + r)
        return g_hat, err

    out = jax.tree_util.tree_map(one, grads, residual)
    g_hat = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_res
