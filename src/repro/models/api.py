"""Unified model API — one facade over the six family implementations.

ModelSpec(cfg) provides:
    schema() / init(rng) / abstract_params()
    loss(params, batch)                      — next-token CE (+ MoE aux)
    forward / prefill / decode_step
    input_specs(shape)                       — ShapeDtypeStruct stand-ins for
                                               every input of the lowered step
    cache_specs / init_cache / cache_pspec   — decode-state handling

Step builders (train_step / prefill_step / serve_step) live in
repro.launch.steps so that distribution concerns stay out of model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common, dense, encdec, mamba2, rwkv6

Pytree = Any

_FAMILY = {
    "dense": dense,
    "moe": dense,
    "vlm": dense,
    "encdec": encdec,
    "ssm": rwkv6,
    "hybrid": mamba2,
}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    cfg: ModelConfig

    @property
    def mod(self):
        return _FAMILY[self.cfg.family]

    # ---- parameters ----
    def schema(self) -> Pytree:
        return self.mod.schema(self.cfg)

    def init(self, rng: jax.Array) -> Pytree:
        return common.init_params(rng, self.schema())

    def abstract_params(self) -> Pytree:
        return common.abstract_params(self.schema())

    def param_count(self) -> int:
        return common.param_count(self.schema())

    # ---- compute ----
    def forward(self, params, tokens, frontend=None, *, remat=True, **kw):
        return self.mod.forward(self.cfg, params, tokens, frontend, remat=remat, **kw)

    def loss(self, params, batch: Dict[str, jax.Array], *, remat: bool = True):
        """Mean next-token cross entropy. Returns (loss, metrics)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        logits, aux, _ = self.forward(
            params, tokens, batch.get("frontend"), remat=remat
        )
        nf = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
        if nf:
            # logits at frontend positions [nf-1, nf+S-1) predict the S text tokens
            pred = jax.lax.dynamic_slice_in_dim(logits, nf - 1, tokens.shape[1], axis=1)
            targets = tokens
        else:
            pred = logits[:, :-1]
            targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        ce = -jnp.mean(ll)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "loss": loss}

    def prefill(self, params, tokens, frontend=None):
        """Full-context forward collecting decode state. Returns
        (last_logits (B, V), cache)."""
        logits, _, collected = self.forward(
            params, tokens, frontend, remat=False, collect_kv=True,
            unembed_last_only=True,
        )
        S = tokens.shape[1]
        cache = self._assemble_cache(collected, S)
        return logits[:, -1], cache

    def _assemble_cache(self, collected, S: int) -> Dict[str, jax.Array]:
        fam = self.cfg.family
        length = jnp.int32(S)
        if fam in ("dense", "moe", "vlm"):
            k, v = collected
            return {"k": k, "v": v, "length": length}
        if fam == "encdec":
            k, v, ck, cv = collected
            return {"k": k, "v": v, "ck": ck, "cv": cv, "length": length}
        if fam == "ssm":
            tm, cm, st = collected
            return {"wkv": st, "tm_prev": tm, "cm_prev": cm, "length": length}
        if fam == "hybrid":
            if self.cfg.shared_attn_every:
                conv, ssm, ak, av = collected
                return {"conv": conv, "ssm": ssm, "attn_k": ak, "attn_v": av,
                        "length": length}
            conv, ssm = collected
            return {"conv": conv, "ssm": ssm, "length": length}
        raise ValueError(fam)

    def decode_step(self, params, cache, tokens, pos):
        return self.mod.decode_step(self.cfg, params, cache, tokens, pos)

    # ---- decode cache ----
    def cache_specs(self, batch: int, max_len: int):
        return self.mod.cache_specs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        return self.mod.init_cache(self.cfg, batch, max_len)

    def cache_pspec(self):
        spec = self.mod.cache_pspec()
        if self.cfg.family == "hybrid" and not self.cfg.shared_attn_every:
            spec = {k: v for k, v in spec.items() if not k.startswith("attn_")}
        return spec

    # ---- input specs (dry-run stand-ins; no allocation) ----
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        d = cfg.d_model
        specs: Dict[str, Any] = {}
        if shape.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if cfg.family == "vlm":
                specs["frontend"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, d), jnp.bfloat16
                )
            elif cfg.family == "encdec":
                specs["frontend"] = jax.ShapeDtypeStruct((B, S // 4, d), jnp.bfloat16)
        elif shape.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if cfg.family == "vlm":
                specs["frontend"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, d), jnp.bfloat16
                )
            elif cfg.family == "encdec":
                specs["frontend"] = jax.ShapeDtypeStruct((B, S // 4, d), jnp.bfloat16)
        elif shape.kind == "decode":
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
            specs["pos"] = jax.ShapeDtypeStruct((), i32)
            specs["cache"] = self.cache_specs(B, S)
        else:
            raise ValueError(shape.kind)
        return specs

    # ---- smoke-test helpers ----
    def smoke_batch(self, rng, batch: int = 2, seq: int = 32) -> Dict[str, jax.Array]:
        cfg = self.cfg
        r1, r2 = jax.random.split(rng)
        out = {"tokens": jax.random.randint(r1, (batch, seq), 0, cfg.vocab, jnp.int32)}
        if cfg.family == "vlm":
            out["frontend"] = jax.random.normal(
                r2, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        elif cfg.family == "encdec":
            out["frontend"] = jax.random.normal(
                r2, (batch, max(seq // 4, 1), cfg.d_model), jnp.bfloat16
            )
        return out


def spec_for(arch_or_cfg) -> ModelSpec:
    if isinstance(arch_or_cfg, ModelConfig):
        return ModelSpec(arch_or_cfg)
    from repro.configs import get_config

    return ModelSpec(get_config(arch_or_cfg))
