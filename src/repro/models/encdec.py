"""Encoder-decoder transformer (whisper-base backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings of shape (B, S_enc = S//4, d) — the
encoder consumes them directly. Positional encoding is sinusoidal for both
stacks (adaptation note in DESIGN.md: whisper uses learned decoder
positions; sinusoidal is rank-equivalent at this scale and keeps the
schema free of max-length constants).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Leaf, stacked
from repro.models.layers import (
    AttnParams,
    use_weight,
    chunked_attention,
    decode_attention,
    gelu_mlp,
    project_qkv,
    rmsnorm,
    shard_hint,
)

Pytree = Any


def _attn_leaves(cfg: ModelConfig, L: int, prefix: str) -> Dict[str, Leaf]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    return {
        f"{prefix}norm": stacked(L, (d,), (None,), init="ones"),
        f"{prefix}wq": stacked(L, (d, H * hd), ("embed", "heads")),
        f"{prefix}wk": stacked(L, (d, KV * hd), ("embed", "kv")),
        f"{prefix}wv": stacked(L, (d, KV * hd), ("embed", "kv")),
        f"{prefix}wo": stacked(L, (H * hd, d), ("heads", "embed")),
    }


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    L, Le = cfg.n_layers, cfg.enc_layers
    enc = {
        **_attn_leaves(cfg, Le, "attn_"),
        "mlp_norm": stacked(Le, (d,), (None,), init="ones"),
        "w_in": stacked(Le, (d, F), ("embed", "ffn")),
        "w_out": stacked(Le, (F, d), ("ffn", "embed")),
    }
    dec = {
        **_attn_leaves(cfg, L, "attn_"),
        **_attn_leaves(cfg, L, "cross_"),
        "mlp_norm": stacked(L, (d,), (None,), init="ones"),
        "w_in": stacked(L, (d, F), ("embed", "ffn")),
        "w_out": stacked(L, (F, d), ("ffn", "embed")),
    }
    return {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "frontend_proj": Leaf((d, d), ("embed", None), scale=0.02),
        "enc": enc,
        "dec": dec,
        "enc_norm": Leaf((d,), (None,), init="ones"),
        "final_norm": Leaf((d,), (None,), init="ones"),
        "lm_head": Leaf((d, V), ("embed", "vocab"), scale=0.02),
    }


def sinusoid(S: int, d: int, offset=0) -> jax.Array:
    pos = (offset + jnp.arange(S))[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _aview(p, prefix) -> AttnParams:
    return AttnParams(
        wq=p[f"{prefix}wq"], wk=p[f"{prefix}wk"], wv=p[f"{prefix}wv"], wo=p[f"{prefix}wo"]
    )


def encode(cfg: ModelConfig, params: Pytree, frames: jax.Array, *, remat=True):
    """frames: (B, S_enc, d) stub frontend embeddings -> (B, S_enc, d)."""
    x = jnp.einsum("bsd,de->bse", frames.astype(jnp.bfloat16), params["frontend_proj"])
    x = x + sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)
    x = shard_hint(x, ("pod", "data"), None, None)

    def body(x, p):
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = project_qkv(cfg, _aview(p, "attn_"), h, None, rope=False)
        o = chunked_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1), use_weight(p["attn_wo"], "model", None))
        h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + gelu_mlp(h, p["w_in"], None, p["w_out"], None)
        return shard_hint(x, ("pod", "data"), None, None), ()

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, p, x, enc_out, *, causal=True):
    """One decoder layer against full sequences. Returns (x, (k, v, ck, cv))."""
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = project_qkv(cfg, _aview(p, "attn_"), h, None, rope=False)
    o = chunked_attention(q, k, v, causal=causal)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1), use_weight(p["attn_wo"], "model", None))
    # cross attention
    h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
    cq, _, _ = project_qkv(cfg, _aview(p, "cross_"), h, None, rope=False)
    hd = cfg.resolved_head_dim
    B, Se, _ = enc_out.shape
    ck = jnp.einsum("bsd,dh->bsh", enc_out, p["cross_wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
    cv = jnp.einsum("bsd,dh->bsh", enc_out, p["cross_wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
    co = chunked_attention(cq, ck, cv, causal=False)
    x = x + jnp.einsum("bsh,hd->bsd", co.reshape(*co.shape[:2], -1), use_weight(p["cross_wo"], "model", None))
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + gelu_mlp(h, p["w_in"], None, p["w_out"], None)
    return shard_hint(x, ("pod", "data"), None, None), (k, v, ck, cv)


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,  # (B, S) decoder tokens
    frontend: jax.Array,  # (B, S_enc, d) frame embeddings
    *,
    remat: bool = True,
    collect_kv: bool = False,
    unembed_last_only: bool = False,
):
    enc_out = encode(cfg, params, frontend, remat=remat)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)
    x = shard_hint(x, ("pod", "data"), None, None)

    def body(x, p):
        x, kv = _dec_block(cfg, p, x, enc_out)
        return x, kv if collect_kv else ()

    fn = jax.checkpoint(body) if remat else body
    x, kvs = jax.lax.scan(fn, x, params["dec"])
    if unembed_last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, use_weight(params["lm_head"], None, "model"))
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    if collect_kv:
        return logits, jnp.float32(0.0), kvs
    return logits, jnp.float32(0.0), None


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    Se = max(max_len // 4, 1)
    self_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    cross_shape = (cfg.n_layers, batch, Se, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(self_shape, dtype),
        "v": jax.ShapeDtypeStruct(self_shape, dtype),
        "ck": jax.ShapeDtypeStruct(cross_shape, dtype),
        "cv": jax.ShapeDtypeStruct(cross_shape, dtype),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        k: jnp.zeros(s.shape, s.dtype) for k, s in cache_specs(cfg, batch, max_len, dtype).items()
    }


def cache_pspec():
    P = jax.sharding.PartitionSpec
    seqsharded = P(None, ("pod", "data"), "model", None, None)
    return {"k": seqsharded, "v": seqsharded, "ck": seqsharded, "cv": seqsharded, "length": P()}


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decoder step against cached self/cross KV. Returns (logits, cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, d)
    B = x.shape[0]
    d = x.shape[-1]
    x = x + sinusoid_at(pos, d).astype(x.dtype)

    def body(x, xs):
        p, k_c, v_c, ck, cv = xs
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = project_qkv(cfg, _aview(p, "attn_"), h, None, rope=False)
        k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, pos, axis=1)
        o = decode_attention(q, k_c, v_c, pos + 1)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), use_weight(p["attn_wo"], "model", None))
        h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        cq, _, _ = project_qkv(cfg, _aview(p, "cross_"), h, None, rope=False)
        Se = ck.shape[1]
        co = decode_attention(cq, ck, cv, jnp.int32(Se))
        x = x + jnp.einsum("bsh,hd->bsd", co.reshape(B, 1, -1), use_weight(p["cross_wo"], "model", None))
        h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + gelu_mlp(h, p["w_in"], None, p["w_out"], None)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, use_weight(params["lm_head"], None, "model"))[:, 0]
    return logits, {**cache, "k": k_new, "v": v_new, "length": pos + 1}


def sinusoid_at(pos, d: int) -> jax.Array:
    """Sinusoidal embedding at a single (traced) position -> (1, 1, d)."""
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
