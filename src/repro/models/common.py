"""Parameter schema system — single source of truth for parameter shapes,
logical sharding axes, and initialization.

Every model family defines ``schema(cfg) -> nested dict of Leaf``. From the
schema we derive:
  * ``init(rng)``            — concrete parameters (smoke tests, examples)
  * ``abstract(schema)``     — ShapeDtypeStruct tree (dry-run lowering)
  * ``pspecs(schema, mesh)`` — PartitionSpec tree (see distributed/sharding.py)

Per-layer parameters are STACKED along a leading "layers" axis so models scan
over depth (keeps HLO size O(1) in depth — mandatory for the 88-layer archs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# Logical axis vocabulary (mapped to mesh axes in distributed/sharding.py):
#   "layers"  — stacked depth (never sharded)
#   "vocab"   — vocabulary dim           -> model axis
#   "embed"   — residual stream dim      -> data axis (FSDP)
#   "heads"   — flattened q_heads*hd     -> model axis
#   "kv"      — flattened kv_heads*hd    -> model axis
#   "ffn"     — MLP hidden dim           -> model axis
#   "inner"   — SSM inner dim            -> model axis
#   "experts" — MoE expert dim           -> model axis (EP)
#   None      — replicated


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # "normal" | "zeros" | "ones"
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(rng: jax.Array, leaf: Leaf) -> jax.Array:
    dtype = jnp.dtype(leaf.dtype)
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    if leaf.init == "normal":
        # fan_in = last dim unless 1-D; stacked layer dim excluded.
        dims = [d for d, a in zip(leaf.shape, leaf.axes) if a != "layers"]
        fan_in = dims[0] if len(dims) > 1 else dims[-1]
        scale = leaf.scale if leaf.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(rng, leaf.shape, jnp.float32)).astype(dtype)
    raise ValueError(leaf.init)


def is_leaf(x: Any) -> bool:
    return isinstance(x, Leaf)


def init_params(rng: jax.Array, schema: Pytree) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_leaf)
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_leaf_init(r, l) for r, l in zip(rngs, leaves)]
    )


def abstract_params(schema: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
        schema,
        is_leaf=is_leaf,
    )


def param_axes(schema: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda l: l.axes, schema, is_leaf=is_leaf)


def param_count(schema: Pytree) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=is_leaf)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def stacked(n_layers: int, shape: Tuple[int, ...], axes, **kw) -> Leaf:
    """A per-layer parameter stacked along the scan (depth) axis."""
    return Leaf((n_layers, *shape), ("layers", *axes), **kw)


# ---------------------------------------------------------------------------
# misc numeric helpers shared by model files
# ---------------------------------------------------------------------------


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    """Vocab padded for clean TP sharding (MaxText-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple


def take_layer(stacked_tree: Pytree, i) -> Pytree:
    """Dynamic-slice layer i out of a stacked parameter tree."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False), stacked_tree
    )
