"""Shared transformer building blocks.

Pure functions over parameter pytrees (see models/common.py for the schema
system). Everything is written to live inside a ``lax.scan`` over stacked
layer parameters, so no Python-level per-layer state is allowed.

Attention memory policy: full (S, S) score materialization is never allowed
for long sequences — ``chunked_attention`` scans over query chunks and is
exact (full key rows per chunk), keeping activation footprint
O(chunk * S) instead of O(S^2). The Pallas flash-attention kernel
(repro.kernels.flash_attention) is the TPU-optimized path; this file is the
portable/jnp path used for CPU smoke tests and as the lowering default
(see DESIGN.md §Kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig

PS = jax.sharding.PartitionSpec


def _current_mesh_axes() -> Tuple[str, ...]:
    """Axis names of whatever mesh context is active (new or legacy), or ()."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.shape_tuple:
            return tuple(n for n, _ in m.shape_tuple)
    except Exception:
        pass
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if not m.empty:
            return tuple(m.axis_names)
    except Exception:
        pass
    return ()


import os as _os

# layout profile (see launch/dryrun.py REPRO_LAYOUT): model code marks the
# batch dim with the literal ("pod", "data") tuple; under the pure-DP
# profile that resolves to ("data", "model") and model-axis activation
# hints are dropped (a replicated-parameter layout must not reshard
# activations onto the model axis).
_BATCH_AXES = tuple(
    _os.environ.get("REPRO_BATCH_AXES", "pod,data").split(",")
)
_MODEL_HINTS = _os.environ.get("REPRO_MODEL_HINTS", "1") != "0"


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """Best-effort sharding constraint.

    Filters requested logical axes against the active mesh's axis names and
    becomes a no-op when no mesh is active (CPU smoke tests) — so model code
    can state its preferred layout unconditionally.
    """
    names = _current_mesh_axes()
    if not names:
        return x
    clean = []
    for a in axes:
        if isinstance(a, (tuple, list)) and tuple(a) == ("pod", "data"):
            a = _BATCH_AXES  # batch-dim marker: resolve per layout profile
        elif a == "model" and not _MODEL_HINTS:
            a = None
        if a is None:
            clean.append(None)
        elif isinstance(a, (tuple, list)):
            kept = tuple(n for n in a if n in names)
            clean.append(kept if kept else None)
        else:
            clean.append(a if a in names else None)
    try:
        return jax.lax.with_sharding_constraint(x, PS(*clean))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# norms / rope / mlp
# ---------------------------------------------------------------------------


# --- beyond-paper optimization (§Perf hillclimb 1): gather FSDP-sharded
# weights at their use site instead of letting the partitioner reduce
# activations. With 2D (data x model) parameter sharding, a contraction
# over the data-sharded dim otherwise lowers to a full-activation psum per
# projection (~200MB each on the 123B arch); re-sharding the weight to
# model-only costs one small all-gather of the layer's weight shards
# (~88MB total) and leaves exactly the two Megatron-mandatory psums per
# block. Toggle via env REPRO_GATHER_WEIGHTS=0 for the baseline lowering.
GATHER_WEIGHTS = _os.environ.get("REPRO_GATHER_WEIGHTS", "1") != "0"


def use_weight(w: jax.Array, *model_axes) -> jax.Array:
    """Constrain a parameter to model-axis-only sharding for compute.

    ``model_axes``: one entry per dim — "model" to keep TP sharding, None
    to gather. No-op when GATHER_WEIGHTS is disabled or no mesh is active.
    """
    if not GATHER_WEIGHTS:
        return w
    return shard_hint(w, *model_axes)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]  # (..., S, 1, hd/2) — broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, use_weight(w_gate, None, "model"))
    u = jnp.einsum("...d,df->...f", x, use_weight(w_up, None, "model"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_hint(h, None, None, "model")
    return jnp.einsum("...f,fd->...d", h, use_weight(w_down, "model", None))


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in, w_out: jax.Array, b_out) -> jax.Array:
    w_in = use_weight(w_in, None, "model")
    w_out = use_weight(w_out, "model", None)
    h = jnp.einsum("...d,df->...f", x, w_in)
    if b_in is not None:
        h = h + b_in
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard_hint(h, None, None, "model")
    o = jnp.einsum("...f,fd->...d", h, w_out)
    if b_out is not None:
        o = o + b_out
    return o


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnParams:
    """View over one layer's attention weights (already layer-sliced)."""

    wq: jax.Array  # (d, H*hd)
    wk: jax.Array  # (d, KV*hd)
    wv: jax.Array  # (d, KV*hd)
    wo: jax.Array  # (H*hd, d)
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None
    q_norm: Optional[jax.Array] = None  # (hd,) qk-norm gains
    k_norm: Optional[jax.Array] = None


def project_qkv(
    cfg: ModelConfig, p: AttnParams, x: jax.Array, positions: Optional[jax.Array],
    *, rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q: (B, S, H, hd), k/v: (B, S, KV, hd)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, use_weight(p.wq, None, "model"))
    k = jnp.einsum("bsd,dh->bsh", x, use_weight(p.wk, None, "model"))
    v = jnp.einsum("bsd,dh->bsh", x, use_weight(p.wv, None, "model"))
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if p.q_norm is not None:
        q = rmsnorm(q, p.q_norm, cfg.norm_eps)
        k = rmsnorm(k, p.k_norm, cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunk(
    q: jax.Array,  # (B, C, H, hd) one query chunk
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    mask: Optional[jax.Array],  # (C, S) True = attend, or None
) -> jax.Array:
    B, C, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV  # GQA group size
    qg = q.reshape(B, C, KV, g, hd)
    scores = jnp.einsum("bckgh,bskh->bkgcs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", w.astype(v.dtype), v)
    return out.reshape(B, C, H, hd)


def chunked_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S_kv, KV, hd)
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Exact attention, scanning over query chunks (memory O(chunk * S_kv)).

    ``q_offset``: position of q[0] relative to k[0] (for decode/cross cases).
    """
    B, S, H, hd = q.shape
    S_kv = k.shape[1]
    chunk = min(chunk, S)
    if S % chunk != 0:  # pad to a multiple (masked out)
        pad = chunk - S % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // chunk
    qs = q.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    kv_pos = jnp.arange(S_kv)

    def body(carry, args):
        qc, idx = args
        if causal:
            q_pos = q_offset + idx * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]
        else:
            mask = None
        return carry, _sdpa_chunk(qc, k, v, mask)

    _, outs = jax.lax.scan(body, 0, (qs, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, H, hd)
    return out[:, :S]


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S_max, KV, hd) — S_max sharded over "model"
    v_cache: jax.Array,
    length: jax.Array,  # () or (B,) valid prefix length
) -> jax.Array:
    """Single-token attention against a (sequence-sharded) KV cache.

    Softmax over the sharded S axis lowers to partial max/sum + psum —
    the flash-decoding schedule — purely via SPMD propagation.
    """
    B, _, H, hd = q.shape
    S_max = k_cache.shape[1]
    KV = k_cache.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    pos = jnp.arange(S_max)
    valid = pos[None, :] < jnp.reshape(length, (-1, 1))  # (B or 1, S)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch; expert dim sharded over "model" = EP)
# ---------------------------------------------------------------------------


def moe_ffn(
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    w_router: jax.Array,  # (d, E)
    w_gate: jax.Array,  # (E, d, f)
    w_up: jax.Array,  # (E, d, f)
    w_down: jax.Array,  # (E, f, d)
    shared: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k capacity-bounded MoE. Returns (out, aux_loss)."""
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    cap = max(1, int(T * k * m.capacity_factor / E))
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1  # (T*k, E)
    pos = pos_in_expert.max(axis=-1).reshape(T, k)  # (T, k)
    expert = idx
    keep = (pos < cap) & (pos >= 0)
    gate_vals = gate_vals * keep

    # dispatch: (E, cap, d)
    dispatch = jnp.zeros((E, cap, d), xt.dtype)
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    dispatch = dispatch.at[expert, jnp.clip(pos, 0, cap - 1)].add(
        jnp.where(keep[..., None], xt[tok_ids], 0)
    )
    dispatch = shard_hint(dispatch, "model", None, None)

    h = jnp.einsum("ecd,edf->ecf", dispatch, use_weight(w_gate, "model", None, None))
    u = jnp.einsum("ecd,edf->ecf", dispatch, use_weight(w_up, "model", None, None))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(xt.dtype) * u
    eo = jnp.einsum("ecf,efd->ecd", h, use_weight(w_down, "model", None, None))

    # combine
    gathered = eo[expert, jnp.clip(pos, 0, cap - 1)]  # (T, k, d)
    out = jnp.einsum("tk,tkd->td", gate_vals.astype(xt.dtype), gathered)

    if shared is not None:
        sg, su, sd = shared
        out = out + swiglu(xt[None], sg, su, sd)[0]

    # aux losses (load balance + router z) — standard formulations
    me = probs.mean(0)  # (E,)
    ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)
    lb = E * jnp.sum(me * ce) * m.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    return out.reshape(B, S, d), lb + z
