"""Decoder-only transformer: families "dense", "moe", "vlm".

vlm = dense backbone + stub vision frontend (precomputed patch embeddings are
an *input*, projected and prepended to the token sequence).
moe = dense with the FFN replaced by a top-k expert layer (EP over "model").

All per-layer parameters are stacked on a leading "layers" axis and the
forward pass is a single ``lax.scan`` (+ optional remat) — HLO size is O(1)
in depth, which keeps 88-layer × 512-device dry-run compiles tractable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Leaf, stacked
from repro.models.layers import (
    AttnParams,
    use_weight,
    chunked_attention,
    decode_attention,
    moe_ffn,
    project_qkv,
    rmsnorm,
    shard_hint,
)

Pytree = Any


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    H, KV, F, V = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab
    s: Dict[str, Any] = {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": Leaf((d,), (None,), init="ones"),
        "blocks": {
            "attn_norm": stacked(L, (d,), (None,), init="ones"),
            "wq": stacked(L, (d, H * hd), ("embed", "heads")),
            "wk": stacked(L, (d, KV * hd), ("embed", "kv")),
            "wv": stacked(L, (d, KV * hd), ("embed", "kv")),
            "wo": stacked(L, (H * hd, d), ("heads", "embed")),
            "mlp_norm": stacked(L, (d,), (None,), init="ones"),
        },
    }
    b = s["blocks"]
    if cfg.qkv_bias:
        b["bq"] = stacked(L, (H * hd,), ("heads",), init="zeros")
        b["bk"] = stacked(L, (KV * hd,), ("kv",), init="zeros")
        b["bv"] = stacked(L, (KV * hd,), ("kv",), init="zeros")
    if cfg.qk_norm:
        b["q_norm"] = stacked(L, (hd,), (None,), init="ones")
        b["k_norm"] = stacked(L, (hd,), (None,), init="ones")
    if cfg.family == "moe":
        m = cfg.moe
        E, f = m.num_experts, m.d_ff_expert
        b["router"] = stacked(L, (d, E), ("embed", None), scale=0.02)
        b["we_gate"] = stacked(L, (E, d, f), ("experts", "embed", None))
        b["we_up"] = stacked(L, (E, d, f), ("experts", "embed", None))
        b["we_down"] = stacked(L, (E, f, d), ("experts", None, "embed"))
        if m.shared_expert:
            fs = m.d_ff_shared or F
            b["ws_gate"] = stacked(L, (d, fs), ("embed", "ffn"))
            b["ws_up"] = stacked(L, (d, fs), ("embed", "ffn"))
            b["ws_down"] = stacked(L, (fs, d), ("ffn", "embed"))
    else:
        b["w_gate"] = stacked(L, (d, F), ("embed", "ffn"))
        b["w_up"] = stacked(L, (d, F), ("embed", "ffn"))
        b["w_down"] = stacked(L, (F, d), ("ffn", "embed"))
    if not cfg.tie_embeddings:
        s["lm_head"] = Leaf((d, V), ("embed", "vocab"), scale=0.02)
    if cfg.frontend is not None:
        s["frontend_proj"] = Leaf((d, d), ("embed", None), scale=0.02)
    return s


def _attn_params(cfg: ModelConfig, p: Dict[str, jax.Array]) -> AttnParams:
    return AttnParams(
        wq=p["wq"],
        wk=p["wk"],
        wv=p["wv"],
        wo=p["wo"],
        bq=p.get("bq"),
        bk=p.get("bk"),
        bv=p.get("bv"),
        q_norm=p.get("q_norm"),
        k_norm=p.get("k_norm"),
    )


def _ffn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array):
    """Returns (out, aux_loss)."""
    if cfg.family == "moe":
        shared = None
        if cfg.moe.shared_expert:
            shared = (p["ws_gate"], p["ws_up"], p["ws_down"])
        return moe_ffn(
            cfg, x, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared
        )
    g = jnp.einsum("bsd,df->bsf", x, use_weight(p["w_gate"], None, "model"))
    u = jnp.einsum("bsd,df->bsf", x, use_weight(p["w_up"], None, "model"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_hint(h, ("pod", "data"), None, "model")
    return jnp.einsum("bsf,fd->bsd", h, use_weight(p["w_down"], "model", None)), jnp.float32(0.0)


def _block(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One layer. Returns (x_out, aux_loss, k, v)."""
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = project_qkv(cfg, _attn_params(cfg, p), h, positions)
    o = chunked_attention(q, k, v, causal=causal)
    o = o.reshape(*o.shape[:2], -1)
    x = x + jnp.einsum("bsh,hd->bsd", o, use_weight(p["wo"], "model", None))
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    f, aux = _ffn(cfg, p, h)
    x = x + f
    x = shard_hint(x, ("pod", "data"), None, None)
    return x, aux, k, v


def embed_inputs(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,  # (B, S)
    frontend: Optional[jax.Array],  # (B, Sf, d) or None
) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend is not None and frontend is not None:
        fe = jnp.einsum("bsd,de->bse", frontend.astype(x.dtype), params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    return shard_hint(x, ("pod", "data"), None, None)


def unembed(cfg: ModelConfig, params: Pytree, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, use_weight(params["embed"], "model", None))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, use_weight(params["lm_head"], None, "model"))
    return shard_hint(logits, ("pod", "data"), None, "model")


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,
    frontend: Optional[jax.Array] = None,
    *,
    remat: bool = True,
    collect_kv: bool = False,
    unembed_last_only: bool = False,
):
    """Full-sequence forward. Returns (logits, aux_loss, kv | None).

    kv (if collected): (k, v) each (L, B, S, KV, hd) — the prefill cache.
    ``unembed_last_only`` skips the (B, S, V) logit tensor (prefill path).
    """
    x = embed_inputs(cfg, params, tokens, frontend)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, p_l):
        x = carry
        x, aux, k, v = _block(cfg, p_l, x, positions)
        ys = (k, v) if collect_kv else (aux,)
        return x, ys

    fn = jax.checkpoint(body) if remat else body
    x, ys = jax.lax.scan(fn, x, params["blocks"])
    if unembed_last_only:
        x = x[:, -1:]
    logits = unembed(cfg, params, x)
    if collect_kv:
        return logits, jnp.float32(0.0), ys
    return logits, jnp.sum(ys[0]), None


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_pspec():
    """KV sequence-sharded over "model" (flash-decoding combine via SPMD),
    batch over ("pod","data") — see DESIGN.md §4."""
    P = jax.sharding.PartitionSpec
    return {
        "k": P(None, ("pod", "data"), "model", None, None),
        "v": P(None, ("pod", "data"), "model", None, None),
        "length": P(),
    }


def decode_step(
    cfg: ModelConfig,
    params: Pytree,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,  # () int32 — current length (uniform across batch)
):
    """One decode step. Returns (logits (B, V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, d)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))

    def body(x, xs):
        p_l, k_c, v_c = xs
        h = rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
        q, k, v = project_qkv(cfg, _attn_params(cfg, p_l), h, positions)
        k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, pos, axis=1)
        o = decode_attention(q, k_c, v_c, pos + 1)
        o = o.reshape(B, 1, -1)
        x = x + jnp.einsum("bsh,hd->bsd", o, use_weight(p_l["wo"], "model", None))
        h = rmsnorm(x, p_l["mlp_norm"], cfg.norm_eps)
        f, _ = _ffn(cfg, p_l, h)
        return x + f, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    logits = unembed(cfg, params, x)[:, 0]
    new_cache = {"k": k_new, "v": v_new, "length": pos + 1}
    return logits, new_cache
