"""RWKV6 ("Finch") — attention-free linear recurrence with data-dependent
decay. Family "ssm" (sub-quadratic: runs the long_500k cell).

Chunked-parallel WKV (training/prefill): within a chunk of C tokens the
recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state per head: K x V)
    y_t = r_t . (S_{t-1} + (u o k_t) v_t^T)

is evaluated with cumulative log-decay differences, which are <= 0 for all
valid (i, j) pairs so the exp never overflows (the standard "decay cube" —
exact, no clamping; memory O(C^2 K) per head, sharded over heads on the
"model" axis). Across chunks the state is carried by a lax.scan. Decode is
the O(1) recurrence.

Numerics: the recurrence runs in fp32; projections in bf16.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Leaf, stacked
from repro.models.layers import rmsnorm, shard_hint, use_weight

Pytree = Any
LORA = 64  # low-rank width of the data-dependent decay projection


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    s = cfg.ssm
    inner = s.heads * s.head_dim
    F = cfg.d_ff
    return {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": Leaf((d,), (None,), init="ones"),
        "lm_head": Leaf((d, V), ("embed", "vocab"), scale=0.02),
        "blocks": {
            "attn_norm": stacked(L, (d,), (None,), init="ones"),
            # token-shift lerp coefficients for (r, k, v, g, w)
            "mu": stacked(L, (5, d), (None, None), init="zeros"),
            "w_r": stacked(L, (d, inner), ("embed", "inner")),
            "w_k": stacked(L, (d, inner), ("embed", "inner")),
            "w_v": stacked(L, (d, inner), ("embed", "inner")),
            "w_g": stacked(L, (d, inner), ("embed", "inner")),
            "w_o": stacked(L, (inner, d), ("inner", "embed")),
            # data-dependent decay: w_t = exp(-exp(w0 + tanh(x W_a) W_b))
            "w0": stacked(L, (inner,), (None,), init="zeros"),
            "w_lora_a": stacked(L, (d, LORA), ("embed", None)),
            "w_lora_b": stacked(L, (LORA, inner), (None, "inner"), scale=0.01),
            # per-head bonus for the current token (tiny -> replicated; the
            # head count (40) does not divide the model axis)
            "u": stacked(L, (s.heads, s.head_dim), (None, None), init="zeros"),
            "ln_x": stacked(L, (inner,), (None,), init="ones"),
            # channel mix
            "mlp_norm": stacked(L, (d,), (None,), init="ones"),
            "mu_c": stacked(L, (2, d), (None, None), init="zeros"),
            "w_ck": stacked(L, (d, F), ("embed", "ffn")),
            "w_cv": stacked(L, (F, d), ("ffn", "embed")),
            "w_cr": stacked(L, (d, d), ("embed", None)),
        },
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B, S, d); prev: (B, 1, d) last token of the previous segment."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu  # mu=0 -> x (identity), mu=1 -> shifted


def wkv_chunked(
    r: jax.Array,  # (B, S, H, K) fp32
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    logw: jax.Array,  # (B, S, H, K) <= 0
    u: jax.Array,  # (H, K)
    state0: jax.Array,  # (B, H, K, V)
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Exact chunked WKV. Returns (y (B,S,H,V), state (B,H,K,V))."""
    B, S, H, K = r.shape
    Vd = v.shape[-1]
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad logw=0 (w=1)
    N = r.shape[1] // C

    def to_chunks(t):
        return t.reshape(B, N, C, H, -1).transpose(1, 0, 3, 2, 4)  # (N,B,H,C,·)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))

    idx = jnp.arange(C)
    strict = idx[:, None] > idx[None, :]  # j < i

    def body(S0, xs):
        rb, kb, vb, wb = xs  # (B,H,C,K/V)
        cum = jnp.cumsum(wb, axis=2)  # (B,H,C,K) logW_i (inclusive)
        cum_prev = cum - wb  # logW_{i-1} (exclusive)
        # intra-chunk scores_{ij} = sum_k r_i k_j exp(cum_prev_i - cum_j), j<i
        diff = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,C,C,K)
        diff = jnp.where(strict[None, None, :, :, None], diff, -jnp.inf)
        scores = jnp.einsum("bhik,bhijk,bhjk->bhij", rb, jnp.exp(diff), kb)
        # current-token bonus: r_i . (u o k_i) v_i
        bonus = jnp.einsum("bhik,hk,bhik->bhi", rb, u, kb)
        y = jnp.einsum("bhij,bhjv->bhiv", scores, vb) + bonus[..., None] * vb
        # initial-state contribution: r_i diag(exp(cum_prev_i)) S0
        a = rb * jnp.exp(cum_prev)
        y = y + jnp.einsum("bhik,bhkv->bhiv", a, S0)
        # state update: S' = diag(exp(cum_C)) S0 + sum_j exp(cum_C - cum_j) k_j v_j
        total = cum[:, :, -1:, :]  # (B,H,1,K)
        kd = kb * jnp.exp(total - cum)
        S1 = jnp.exp(total[:, :, 0, :, None]) * S0 + jnp.einsum("bhjk,bhjv->bhkv", kd, vb)
        return S1, y

    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, N * C, H, Vd)
    return y[:, :S], state


def time_mix(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, prev: jax.Array,
    state0: jax.Array, chunk: int = 64,
):
    """RWKV6 time-mix over a segment. Returns (out, last_x, state)."""
    s = cfg.ssm
    B, S, d = x.shape
    xs = _token_shift(x, prev)
    xr, xk, xv, xg, xw = (_lerp(x, xs, p["mu"][i]) for i in range(5))
    r = jnp.einsum("bsd,di->bsi", xr, use_weight(p["w_r"], None, "model"))
    k = jnp.einsum("bsd,di->bsi", xk, use_weight(p["w_k"], None, "model"))
    v = jnp.einsum("bsd,di->bsi", xv, use_weight(p["w_v"], None, "model"))
    g = jax.nn.silu(jnp.einsum("bsd,di->bsi", xg, use_weight(p["w_g"], None, "model")).astype(jnp.float32))
    dlr = jnp.einsum(
        "bsl,li->bsi", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"])), p["w_lora_b"]
    )
    logw = -jnp.exp(jnp.clip((p["w0"] + dlr).astype(jnp.float32), -10.0, 5.0))

    def heads(t):
        return t.reshape(B, S, s.heads, s.head_dim).astype(jnp.float32)

    y, state = wkv_chunked(heads(r), heads(k), heads(v), heads(logw), p["u"].astype(jnp.float32), state0, chunk)
    y = y.reshape(B, S, -1)
    # per-head group norm (gain only), then output gate
    yh = y.reshape(B, S, s.heads, s.head_dim)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, -1) * p["ln_x"].astype(jnp.float32)) * g
    y = shard_hint(y.astype(x.dtype), ("pod", "data"), None, "model")
    out = jnp.einsum("bsi,id->bsd", y, use_weight(p["w_o"], "model", None))
    return out, x[:, -1:], state


def channel_mix(cfg, p, x, prev):
    xs = _token_shift(x, prev)
    xk = _lerp(x, xs, p["mu_c"][0])
    xr = _lerp(x, xs, p["mu_c"][1])
    k = jnp.einsum("bsd,df->bsf", xk, use_weight(p["w_ck"], None, "model"))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = shard_hint(k, ("pod", "data"), None, "model")
    kv = jnp.einsum("bsf,fd->bsd", k, use_weight(p["w_cv"], "model", None))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_cr"]).astype(jnp.float32))
    return (rgate * kv.astype(jnp.float32)).astype(x.dtype), x[:, -1:]


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,
    frontend=None,
    *,
    remat: bool = True,
    collect_kv: bool = False,
    unembed_last_only: bool = False,
):
    """Full-sequence forward (zero initial state). Returns (logits, aux, state)."""
    s = cfg.ssm
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_hint(x, ("pod", "data"), None, None)
    B, S, d = x.shape
    zero_prev = jnp.zeros((B, 1, d), x.dtype)
    zero_state = jnp.zeros((B, s.heads, s.head_dim, s.head_dim), jnp.float32)

    def body(x, p):
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        out, last_tm, st = time_mix(cfg, p, h, zero_prev, zero_state, s.chunk)
        x = x + out
        h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        out, last_cm = channel_mix(cfg, p, h, zero_prev)
        x = x + out
        ys = (last_tm, last_cm, st) if collect_kv else ()
        return shard_hint(x, ("pod", "data"), None, None), ys

    fn = jax.checkpoint(body) if remat else body
    x, ys = jax.lax.scan(fn, x, params["blocks"])
    if unembed_last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, use_weight(params["lm_head"], None, "model"))
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, jnp.float32(0.0), ys if collect_kv else None


# ---------------------------------------------------------------------------
# decode — O(1) state recurrence
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    L, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": jax.ShapeDtypeStruct((L, batch, s.heads, s.head_dim, s.head_dim), jnp.float32),
        "tm_prev": jax.ShapeDtypeStruct((L, batch, 1, d), dtype),
        "cm_prev": jax.ShapeDtypeStruct((L, batch, 1, d), dtype),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in cache_specs(cfg, batch, max_len, dtype).items()}


def cache_pspec():
    P = jax.sharding.PartitionSpec
    return {
        "wkv": P(None, ("pod", "data"), "model", None, None),
        "tm_prev": P(None, ("pod", "data"), None, None),
        "cm_prev": P(None, ("pod", "data"), None, None),
        "length": P(),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    s = cfg.ssm
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, d)

    def body(x, xs):
        p, S0, tm_prev, cm_prev = xs
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        out, last, S1 = time_mix(cfg, p, h, tm_prev, S0, chunk=1)
        x = x + out
        h2 = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        out, last_c = channel_mix(cfg, p, h2, cm_prev)
        x = x + out
        return x, (S1, last, last_c)

    x, (wkv, tm_prev, cm_prev) = jax.lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["tm_prev"], cache["cm_prev"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {
        "wkv": wkv,
        "tm_prev": tm_prev,
        "cm_prev": cm_prev,
        "length": pos + 1,
    }
