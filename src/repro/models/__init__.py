from repro.models.api import ModelSpec, spec_for  # noqa: F401
