"""Mamba2 (SSD) layers + the Zamba2 hybrid (family "hybrid").

Zamba2 = a Mamba2 backbone with one *shared* full-attention block applied
after every ``shared_attn_every`` Mamba layers (the paper's per-invocation
LoRA deltas on the shared block are simplified to fully shared weights —
recorded in DESIGN.md). 81 layers with every=6 gives 13 attention
invocations + 3 trailing Mamba layers; the forward is an outer scan over
13 super-blocks (inner scan over 6 Mamba layers, then the shared block) so
HLO stays O(1) in depth while each attention invocation keeps its own KV
cache slice.

Mamba2 recurrence (per head h, scalar decay):
    a_t = exp(-exp(A_log_h) * dt_t)
    S_t = a_t S_{t-1} + dt_t x_t (x) B_t         state: (P=head_dim, N=state)
    y_t = C_t . S_t + D_h x_t
Chunked-parallel evaluation with cumulative log-decay differences (<= 0,
overflow-free), O(C^2) score matrices per head. fp32 recurrence.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Leaf, stacked
from repro.models.layers import (
    AttnParams,
    use_weight,
    chunked_attention,
    decode_attention,
    project_qkv,
    rmsnorm,
    shard_hint,
    swiglu,
)

Pytree = Any


def _mamba_leaves(cfg: ModelConfig, L: int) -> Dict[str, Leaf]:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.heads * s.head_dim
    N = s.state_dim
    return {
        "norm": stacked(L, (d,), (None,), init="ones"),
        "w_z": stacked(L, (d, inner), ("embed", "inner")),
        "w_x": stacked(L, (d, inner), ("embed", "inner")),
        "w_B": stacked(L, (d, N), ("embed", None)),
        "w_C": stacked(L, (d, N), ("embed", None)),
        "w_dt": stacked(L, (d, s.heads), ("embed", None)),
        "dt_bias": stacked(L, (s.heads,), (None,), init="zeros"),
        "A_log": stacked(L, (s.heads,), (None,), init="zeros"),
        "D": stacked(L, (s.heads,), (None,), init="ones"),
        # depthwise causal conv over (x, B, C) channels, width conv_dim
        "conv_w": stacked(L, (inner + 2 * N, s.conv_dim), (None, None), scale=0.3),
        "ln_y": stacked(L, (inner,), (None,), init="ones"),
        "w_out": stacked(L, (inner, d), ("inner", "embed")),
    }


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd = cfg.resolved_head_dim
    H, KV, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    s: Dict[str, Any] = {
        "embed": Leaf((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": Leaf((d,), (None,), init="ones"),
        "lm_head": Leaf((d, V), ("embed", "vocab"), scale=0.02),
        "mamba": _mamba_leaves(cfg, L),
    }
    if cfg.shared_attn_every:
        s["shared_attn"] = {
            "attn_norm": Leaf((d,), (None,), init="ones"),
            "wq": Leaf((d, H * hd), ("embed", "heads")),
            "wk": Leaf((d, KV * hd), ("embed", "kv")),
            "wv": Leaf((d, KV * hd), ("embed", "kv")),
            "wo": Leaf((H * hd, d), ("heads", "embed")),
            "mlp_norm": Leaf((d,), (None,), init="ones"),
            "w_gate": Leaf((d, F), ("embed", "ffn")),
            "w_up": Leaf((d, F), ("embed", "ffn")),
            "w_down": Leaf((F, d), ("ffn", "embed")),
        }
    return s


def _split_counts(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_super_blocks, every, n_trailing)."""
    every = cfg.shared_attn_every
    if not every:
        return 0, 0, cfg.n_layers
    n_super = cfg.n_layers // every
    return n_super, every, cfg.n_layers - n_super * every


def causal_conv(x: jax.Array, w: jax.Array, prev: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, Ch), w: (Ch, W), prev: (B, W-1, Ch)."""
    W = w.shape[-1]
    xp = jnp.concatenate([prev, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def ssd_chunked(
    xh: jax.Array,  # (B, S, H, P) fp32 — dt-scaled inputs NOT yet applied
    dt: jax.Array,  # (B, S, H) fp32 softplus'd
    loga: jax.Array,  # (B, S, H) <= 0 per-token log decay
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    state0: jax.Array,  # (B, H, P, N)
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), state1)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    NC = xh.shape[1] // C

    xc = xh.reshape(B, NC, C, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, NC, C, H).transpose(1, 0, 2, 3)
    lac = loga.reshape(B, NC, C, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, NC, C, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, NC, C, N).transpose(1, 0, 2, 3)

    idx = jnp.arange(C)
    lower = idx[:, None] >= idx[None, :]  # j <= i (diagonal included)

    def body(S0, xs):
        xb, dtb, lab, Bb, Cb = xs  # (B,C,H,P) (B,C,H) (B,C,H) (B,C,N) (B,C,N)
        cum = jnp.cumsum(lab, axis=1)  # (B,C,H) inclusive
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Ci,Cj,H) <= 0 on mask
        decay = jnp.where(lower[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bin,bjn->bij", Cb, Bb)  # (B,Ci,Cj) shared across heads
        dtx = xb * dtb[..., None]  # (B,C,H,P)
        y = jnp.einsum("bij,bijh,bjhp->bihp", cb, decay, dtx)
        # initial state: y_i += C_i . (exp(cum_i) S0)
        y = y + jnp.einsum("bin,bhpn,bih->bihp", Cb, S0, jnp.exp(cum))
        # state update
        total = cum[:, -1:, :]  # (B,1,H)
        w = jnp.exp(total - cum)  # (B,C,H)
        S1 = jnp.exp(total[:, 0, :, None, None]) * S0 + jnp.einsum(
            "bjh,bjhp,bjn->bhpn", w, dtx, Bb
        )
        return S1, y

    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), (xc, dtc, lac, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, NC * C, H, P)
    return y[:, :S], state


def mamba_mix(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d)
    conv_prev: jax.Array,  # (B, W-1, inner+2N)
    state0: jax.Array,  # (B, H, P, N)
):
    """One Mamba2 mixer. Returns (out, conv_state, ssm_state)."""
    s = cfg.ssm
    B, S, d = x.shape
    H, P, N = s.heads, s.head_dim, s.state_dim
    z = jnp.einsum("bsd,di->bsi", x, use_weight(p["w_z"], None, "model"))
    xs = jnp.einsum("bsd,di->bsi", x, use_weight(p["w_x"], None, "model"))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = causal_conv(conv_in, p["conv_w"], conv_prev)
    inner = H * P
    xs, Bm, Cm = (
        conv_out[..., :inner],
        conv_out[..., inner : inner + N],
        conv_out[..., inner + N :],
    )
    # correct for any S including decode (S=1): window = last W-1 inputs seen
    new_conv_prev = jnp.concatenate([conv_prev, conv_in], axis=1)[:, -(s.conv_dim - 1) :]

    dt = jax.nn.softplus((dt_raw + p["dt_bias"]).astype(jnp.float32))  # (B,S,H)
    loga = -jnp.exp(jnp.clip(p["A_log"].astype(jnp.float32), -8.0, 4.0)) * dt
    xh = xs.reshape(B, S, H, P).astype(jnp.float32)
    y, state1 = ssd_chunked(
        xh, dt, loga, Bm.astype(jnp.float32), Cm.astype(jnp.float32), state0, s.chunk
    )
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, inner)
    # gated rmsnorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-5)
    y = (y * p["ln_y"].astype(jnp.float32)).astype(x.dtype)
    y = shard_hint(y, ("pod", "data"), None, "model")
    out = jnp.einsum("bsi,id->bsd", y, use_weight(p["w_out"], "model", None))
    return out, new_conv_prev, state1


def _mamba_layer(cfg, p, x, conv_prev, state0):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    out, conv_state, ssm_state = mamba_mix(cfg, p, h, conv_prev, state0)
    return x + out, conv_state, ssm_state


def _shared_attn_block(cfg, p, x, positions, *, kv_cache=None, pos=None):
    """Full-seq (kv_cache=None) or decode-mode shared attention block."""
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    ap = AttnParams(wq=p["wq"], wk=p["wk"], wv=p["wv"], wo=p["wo"])
    q, k, v = project_qkv(cfg, ap, h, positions)
    if kv_cache is None:
        o = chunked_attention(q, k, v, causal=True)
        new_cache = (k, v)
    else:
        k_c, v_c = kv_cache
        k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, pos, axis=1)
        o = decode_attention(q, k_c, v_c, pos + 1)
        new_cache = (k_c, v_c)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x, new_cache


def _zero_states(cfg: ModelConfig, B: int, dtype):
    s = cfg.ssm
    conv = jnp.zeros((B, s.conv_dim - 1, s.heads * s.head_dim + 2 * s.state_dim), dtype)
    ssm = jnp.zeros((B, s.heads, s.head_dim, s.state_dim), jnp.float32)
    return conv, ssm


def _reshape_super(tree: Pytree, n_super: int, every: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda t: t[: n_super * every].reshape(n_super, every, *t.shape[1:]), tree
    )


def _tail(tree: Pytree, n_tail: int) -> Pytree:
    return jax.tree_util.tree_map(lambda t: t[t.shape[0] - n_tail :], tree)


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,
    frontend=None,
    *,
    remat: bool = True,
    collect_kv: bool = False,
    unembed_last_only: bool = False,
):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_hint(x, ("pod", "data"), None, None)
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    conv0, ssm0 = _zero_states(cfg, B, x.dtype)
    n_super, every, n_tail = _split_counts(cfg)

    def mamba_scan(x, blocks):
        def body(x, p):
            x, conv_st, ssm_st = _mamba_layer(cfg, p, x, conv0, ssm0)
            ys = (conv_st, ssm_st) if collect_kv else ()
            return x, ys

        fn = jax.checkpoint(body) if remat else body
        return jax.lax.scan(fn, x, blocks)

    collected = []
    if n_super:
        super_blocks = _reshape_super(params["mamba"], n_super, every)

        def super_body(x, p_super):
            x, states = mamba_scan(x, p_super)
            x, kv = _shared_attn_block(cfg, params["shared_attn"], x, positions)
            return x, (states, kv if collect_kv else ())

        fn = jax.checkpoint(super_body) if remat else super_body
        x, (states, attn_kv) = jax.lax.scan(fn, x, super_blocks)
        if collect_kv:
            collected.append(jax.tree_util.tree_map(lambda t: t.reshape(-1, *t.shape[2:]), states))
            collected_attn = attn_kv  # (n_super, B, S, KV, hd) x2
    if n_tail:
        x, states = mamba_scan(x, _tail(params["mamba"], n_tail))
        if collect_kv:
            collected.append(states)

    if unembed_last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, use_weight(params["lm_head"], None, "model"))
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    states = None
    if collect_kv and collected:
        states = jax.tree_util.tree_map(lambda *t: jnp.concatenate(t, 0), *collected) \
            if len(collected) > 1 else collected[0]
        if n_super:
            states = (*states, *collected_attn)  # (conv, ssm, attn_k, attn_v)
    return logits, jnp.float32(0.0), states


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    n_super, _, _ = _split_counts(cfg)
    conv_ch = s.heads * s.head_dim + 2 * s.state_dim
    specs = {
        "conv": jax.ShapeDtypeStruct((L, batch, s.conv_dim - 1, conv_ch), dtype),
        "ssm": jax.ShapeDtypeStruct((L, batch, s.heads, s.head_dim, s.state_dim), jnp.float32),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if n_super:
        kv = (n_super, batch, max_len, cfg.n_kv_heads, hd)
        specs["attn_k"] = jax.ShapeDtypeStruct(kv, dtype)
        specs["attn_v"] = jax.ShapeDtypeStruct(kv, dtype)
    return specs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in cache_specs(cfg, batch, max_len, dtype).items()}


def cache_pspec():
    P = jax.sharding.PartitionSpec
    return {
        "conv": P(None, ("pod", "data"), None, None),
        "ssm": P(None, ("pod", "data"), None, None, None),
        "attn_k": P(None, ("pod", "data"), "model", None, None),
        "attn_v": P(None, ("pod", "data"), "model", None, None),
        "length": P(),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = jnp.take(params["embed"], tokens, axis=0)  # (B,1,d)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    n_super, every, n_tail = _split_counts(cfg)

    def mamba_body(x, xs):
        p, conv_st, ssm_st = xs
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        out, conv1, ssm1 = mamba_mix(cfg, p, h, conv_st, ssm_st)
        return x + out, (conv1, ssm1)

    new_conv, new_ssm, new_k, new_v = [], [], None, None
    if n_super:
        mb = _reshape_super(params["mamba"], n_super, every)
        conv_s = cache["conv"][: n_super * every].reshape(n_super, every, *cache["conv"].shape[1:])
        ssm_s = cache["ssm"][: n_super * every].reshape(n_super, every, *cache["ssm"].shape[1:])

        def super_body(x, xs):
            p_super, conv_b, ssm_b, k_c, v_c = xs
            x, states = jax.lax.scan(mamba_body, x, (p_super, conv_b, ssm_b))
            x, (k1, v1) = _shared_attn_block(
                cfg, params["shared_attn"], x, positions, kv_cache=(k_c, v_c), pos=pos
            )
            return x, (states[0], states[1], k1, v1)

        x, (conv1, ssm1, new_k, new_v) = jax.lax.scan(
            super_body, x, (mb, conv_s, ssm_s, cache["attn_k"], cache["attn_v"])
        )
        new_conv.append(conv1.reshape(-1, *conv1.shape[2:]))
        new_ssm.append(ssm1.reshape(-1, *ssm1.shape[2:]))
    if n_tail:
        x, (conv1, ssm1) = jax.lax.scan(
            mamba_body,
            x,
            (_tail(params["mamba"], n_tail), cache["conv"][cfg.n_layers - n_tail :],
             cache["ssm"][cfg.n_layers - n_tail :]),
        )
        new_conv.append(conv1)
        new_ssm.append(ssm1)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    new_cache = {
        "conv": jnp.concatenate(new_conv, 0),
        "ssm": jnp.concatenate(new_ssm, 0),
        "length": pos + 1,
    }
    if n_super:
        new_cache["attn_k"] = new_k
        new_cache["attn_v"] = new_v
    return logits, new_cache
