"""Pallas TPU kernel: write-log compaction.

Grid = (F, L): one step per (flush target, layer). The target page is
merged in VMEM: start from the current page content, overlay every
matching log token at its in-page offset (newest-wins by slot order),
write back — ONE page-granular HBM write per flushed page, which is the
whole point of the paper's coalescing (vs one page write per token).
The log block rides in VMEM (the log is small by design: SkyByte sizes it
at 1/8 of SSD DRAM; here <=2MB so it fits VMEM comfortably).
flush target metadata rides in SMEM via scalar prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    targets,  # (F, 3) SMEM: (request, logical_page, pool_slot)
    meta,  # (S, 2) SMEM
    logk_ref,  # (1, S, KV, hd)
    logv_ref,
    kp_in,  # (1, 1, page, KV, hd) current page content (gathered by index_map)
    vp_in,
    kp_out,  # (1, 1, page, KV, hd)
    vp_out,
    *,
    page: int,
    n_slots: int,
):
    f = pl.program_id(0)
    r = targets[f, 0]
    logical = targets[f, 1]

    kp_out[...] = kp_in[...]
    vp_out[...] = vp_in[...]

    def body(s, _):
        owner = meta[s, 0]
        lpos = meta[s, 1]
        match = (owner == r) & (r >= 0) & (lpos >= 0) & (lpos // page == logical)

        @pl.when(match)
        def _store():
            off = lpos % page
            kp_out[0, 0, pl.dslice(off, 1)] = logk_ref[0, pl.dslice(s, 1)].astype(
                kp_out.dtype
            )
            vp_out[0, 0, pl.dslice(off, 1)] = logv_ref[0, pl.dslice(s, 1)].astype(
                vp_out.dtype
            )

        return ()

    jax.lax.fori_loop(0, n_slots, body, ())


@functools.partial(jax.jit, static_argnames=("interpret",))
def log_compact_pallas(
    k_pages: jax.Array,  # (L, P, page, KV, hd)
    v_pages: jax.Array,
    log_k: jax.Array,  # (L, S, KV, hd)
    log_v: jax.Array,
    log_meta: jax.Array,  # (S, 2)
    flush_targets: jax.Array,  # (F, 3)
    *,
    interpret: bool = True,
):
    L, P, page, KV, hd = k_pages.shape
    S = log_k.shape[1]
    F = flush_targets.shape[0]

    def logmap(f, l, tg, mt):
        return (l, 0, 0, 0)

    def pagemap(f, l, tg, mt):
        return (l, jnp.maximum(tg[f, 2], 0), 0, 0, 0)

    kernel = functools.partial(_kernel, page=page, n_slots=S)
    # emit merged pages (F, L, page, KV, hd); scatter back outside (the
    # in-kernel aliased scatter would need dynamic output indexing)
    merged_k, merged_v = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(F, L),
            in_specs=[
                pl.BlockSpec((1, S, KV, hd), lambda f, l, tg, mt: (l, 0, 0, 0)),
                pl.BlockSpec((1, S, KV, hd), lambda f, l, tg, mt: (l, 0, 0, 0)),
                pl.BlockSpec((1, 1, page, KV, hd), pagemap),
                pl.BlockSpec((1, 1, page, KV, hd), pagemap),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, page, KV, hd), lambda f, l, tg, mt: (l, f, 0, 0, 0)),
                pl.BlockSpec((1, 1, page, KV, hd), lambda f, l, tg, mt: (l, f, 0, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((L, F, page, KV, hd), k_pages.dtype),
            jax.ShapeDtypeStruct((L, F, page, KV, hd), v_pages.dtype),
        ],
        interpret=interpret,
    )(flush_targets, log_meta, log_k, log_v, k_pages, v_pages)

    # scatter merged pages into the pool (slot -1 -> discarded via clamp+where)
    slots = flush_targets[:, 2]
    valid = (flush_targets[:, 0] >= 0) & (slots >= 0)
    safe = jnp.maximum(slots, 0)
    cur_k = k_pages[:, safe]  # (L, F, page, KV, hd)
    cur_v = v_pages[:, safe]
    sel_k = jnp.where(valid[None, :, None, None, None], merged_k, cur_k)
    sel_v = jnp.where(valid[None, :, None, None, None], merged_v, cur_v)
    k_pages = k_pages.at[:, safe].set(sel_k)
    v_pages = v_pages.at[:, safe].set(sel_v)
    return k_pages, v_pages
