from repro.kernels.log_compact.ops import log_compact  # noqa: F401
