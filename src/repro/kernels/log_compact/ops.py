"""Dispatch wrapper for log compaction."""
from __future__ import annotations

from repro.kernels.log_compact.kernel import log_compact_pallas
from repro.kernels.log_compact.ref import log_compact_ref


def log_compact(
    k_pages, v_pages, log_k, log_v, log_meta, flush_targets,
    *, use_pallas: bool = True, interpret: bool = True,
):
    if not use_pallas:
        return log_compact_ref(k_pages, v_pages, log_k, log_v, log_meta, flush_targets)
    return log_compact_pallas(
        k_pages, v_pages, log_k, log_v, log_meta, flush_targets, interpret=interpret
    )
