"""Pure-jnp oracle: write-log compaction (coalesce log tokens into pages).

For each flush target f (request r, logical page p, pool slot s), every
log entry whose (request, abs_pos // page_size) matches (r, p) is written
into page-pool slot s at offset abs_pos % page_size. Later log slots win
(newest-wins — with append-only KV there are no conflicts, but the
semantics match the paper's log compaction exactly).

flush_targets: (F, 3) int32 rows (request, logical_page, pool_slot);
request = -1 padding rows are ignored. PRECONDITION (engine-guaranteed):
rows reference distinct (request, logical_page) pairs and distinct pool
slots — duplicate slots would be order-dependent.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def log_compact_ref(
    k_pages: jax.Array,  # (L, P, page, KV, hd)
    v_pages: jax.Array,
    log_k: jax.Array,  # (L, S, KV, hd)
    log_v: jax.Array,
    log_meta: jax.Array,  # (S, 2)
    flush_targets: jax.Array,  # (F, 3)
) -> Tuple[jax.Array, jax.Array]:
    L, P, page, KV, hd = k_pages.shape
    S = log_k.shape[1]
    owner, lpos = log_meta[:, 0], log_meta[:, 1]

    def one_target(carry, tgt):
        kp, vp = carry
        r, logical, slot = tgt[0], tgt[1], tgt[2]
        match = (owner == r) & (r >= 0) & (lpos >= 0) & (lpos // page == logical)
        offs = jnp.where(match, lpos % page, page)  # page = scratch row
        # scatter (with a discard row at index `page`)
        def per_layer(kp_l, vp_l, lk_l, lv_l):
            buf_k = jnp.zeros((page + 1, KV, hd), kp_l.dtype)
            buf_v = jnp.zeros((page + 1, KV, hd), vp_l.dtype)
            wrote = jnp.zeros((page + 1,), bool).at[offs].set(True)[:page]
            buf_k = buf_k.at[offs].set(lk_l.astype(kp_l.dtype))[:page]
            buf_v = buf_v.at[offs].set(lv_l.astype(vp_l.dtype))[:page]
            old_k = kp_l[jnp.maximum(slot, 0)]
            old_v = vp_l[jnp.maximum(slot, 0)]
            merged_k = jnp.where(wrote[:, None, None], buf_k, old_k)
            merged_v = jnp.where(wrote[:, None, None], buf_v, old_v)
            kp_l = kp_l.at[jnp.maximum(slot, 0)].set(
                jnp.where(r >= 0, merged_k, old_k)
            )
            vp_l = vp_l.at[jnp.maximum(slot, 0)].set(
                jnp.where(r >= 0, merged_v, old_v)
            )
            return kp_l, vp_l

        kp, vp = jax.vmap(per_layer)(kp, vp, log_k, log_v)
        return (kp, vp), ()

    (k_pages, v_pages), _ = jax.lax.scan(one_target, (k_pages, v_pages), flush_targets)
    return k_pages, v_pages
