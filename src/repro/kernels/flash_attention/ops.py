"""Dispatch wrapper for the prefill flash-attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    use_pallas: bool = True,
    interpret: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if not use_pallas:
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
