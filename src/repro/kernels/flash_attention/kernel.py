"""Pallas TPU kernel: tiled causal flash attention (prefill hot path).

Grid = (B, H, n_q_blocks, n_k_blocks); k-block axis is minor-most so the
online-softmax state is carried in VMEM scratch across k steps. Causal
blocks that are fully masked are skipped with pl.when (no MXU work).
Block shapes (block_q x hd) / (block_k x hd) are (128, 128)-aligned for
the MXU; fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, block_q, 1, hd)
    k_ref,  # (1, block_k, 1, hd)
    v_ref,
    out_ref,  # (1, block_q, 1, hd)
    acc,  # (block_q, hd) f32
    m_scr,  # (block_q, 1)
    l_scr,  # (block_q, 1)
    *,
    block_q: int,
    block_k: int,
    n_k: int,
    causal: bool,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    run = True
    if causal:
        run = ik * block_k <= iq * block_q + block_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0].astype(jnp.float32)
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(1.0 * hd)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _done():
        out_ref[0, :, 0] = (acc[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            out_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S_kv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    S_kv, KV = k.shape[1], k.shape[2]
    g = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S_kv)
    assert S % block_q == 0 and S_kv % block_k == 0, (S, S_kv, block_q, block_k)
    n_q, n_k = S // block_q, S_kv // block_k

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_k=n_k, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda b, h, iq, ik: (b, ik, h // g, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda b, h, iq, ik: (b, ik, h // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
