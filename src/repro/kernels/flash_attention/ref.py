"""Pure-jnp oracle: causal (or full) GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S_kv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, S, KV, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(1.0 * hd)
    if causal:
        S_kv = k.shape[1]
        mask = jnp.arange(S_kv)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
