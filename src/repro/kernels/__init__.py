"""Pallas TPU kernels for the SkyByte tiering runtime's compute hot spots.

Each kernel package has:
  kernel.py — pl.pallas_call + BlockSpec TPU implementation
  ops.py    — jitted dispatch wrapper (``use_pallas`` flag; interpret=True
              executes the kernel body on CPU for validation)
  ref.py    — pure-jnp oracle

Kernels:
  paged_attention — decode attention over the paged HBM KV cache + the
                    token-granular write log (the paper's parallel
                    log+cache lookup, SIII-B read path)
  kv_log_append   — token append into the KV write-log ring (write path)
  log_compact     — newest-wins coalescing of log tokens into KV pages
                    (SIII-B log compaction)
  flash_attention — tiled causal attention for prefill (MXU-aligned)
"""
