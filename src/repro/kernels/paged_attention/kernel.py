"""Pallas TPU kernel: decode attention over a paged KV pool.

Flash-decoding schedule: grid = (B, KV_heads, N_pages); the page axis is
the sequential minor-most grid dimension, so the online-softmax state
(m, l, acc) lives in VMEM scratch and is carried across page steps.
The page table and lengths ride in SMEM via PrefetchScalarGridSpec, and
each k/v page block is streamed HBM->VMEM by the BlockSpec index_map
*through the page table* — non-resident pages (slot -1) are masked, never
fetched twice (the paper's MSHR-free parallel lookup, adapted: the page
table here plays the role of SkyByte's two-level index).

Block shapes: (page_size, head_dim) tiles — page_size x hd multiples of
(8, 128) keep the MXU/VPU aligned; fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    page_table,  # (B, N) int32 in SMEM
    lengths,  # (B,) int32 in SMEM
    # blocks
    q_ref,  # (1, 1, g, hd)
    k_ref,  # (1, page, 1, hd)
    v_ref,  # (1, page, 1, hd)
    out_ref,  # (1, 1, g, hd)
    m_ref,  # (1, 1, g, 1) fp32 running max (output)
    l_ref,  # (1, 1, g, 1) fp32 running denom (output)
    # scratch
    acc,  # (g, hd) fp32
    m_scr,  # (g, 1) fp32
    l_scr,  # (g, 1) fp32
    *,
    page: int,
    n_pages: int,
):
    b = pl.program_id(0)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
    hd = q.shape[-1]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(1.0 * hd)  # (g, page)

    pos = n * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    resident = page_table[b, n] >= 0
    valid = (pos < lengths[b]) & resident
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]  # (g, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)  # (g, page)
    l_cur = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(n == n_pages - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out_ref[0, 0] = (acc[...] / denom).astype(out_ref.dtype)
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(
    q: jax.Array,  # (B, H, hd)
    k_pages: jax.Array,  # (P, page, KV, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, N) int32
    lengths: jax.Array,  # (B,) int32
    *,
    interpret: bool = True,
):
    """Returns (out (B, H, hd), m (B, KV, g, 1), l (B, KV, g, 1))."""
    B, H, hd = q.shape
    P, page, KV, _ = k_pages.shape
    N = page_table.shape[1]
    g = H // KV
    qg = q.reshape(B, KV, g, hd)

    grid = (B, KV, N)

    def qmap(b, kv, n, pt, ln):
        return (b, kv, 0, 0)

    def kvmap(b, kv, n, pt, ln):
        return (jnp.maximum(pt[b, n], 0), 0, kv, 0)

    def omap(b, kv, n, pt, ln):
        return (b, kv, 0, 0)

    kernel = functools.partial(_kernel, page=page, n_pages=N)
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), qmap),
                pl.BlockSpec((1, page, 1, hd), kvmap),
                pl.BlockSpec((1, page, 1, hd), kvmap),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, g, hd), omap),
                pl.BlockSpec((1, 1, g, 1), omap),
                pl.BlockSpec((1, 1, g, 1), omap),
            ],
            scratch_shapes=[
                pltpu.VMEM((g, hd), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
            jax.ShapeDtypeStruct((B, KV, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, hd), m, l
