"""Dispatch wrapper: paged decode attention (+ optional write-log merge).

The Pallas kernel covers the page pool; the (small) write log is attended
with a jnp pass and merged via the standard flash-decoding (m, l)
combination — numerically identical to attending the concatenation, and it
keeps the log's irregular (request-interleaved) layout out of the kernel's
tiling. Runtime invariant (append-only KV): a logical position lives in
EITHER the log or a page, never both, so the merge needs no shadowing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import paged_decode_attention_ref

NEG_INF = -1e30


def _log_attention(q, log_k, log_v, log_meta, lengths, req_ids):
    """jnp attention over the write-log ring. Returns (out, m, l)."""
    B, H, hd = q.shape
    S, KV, _ = log_k.shape
    g = H // KV
    qg = q.reshape(B, KV, g, hd).astype(jnp.float32)
    k = log_k.astype(jnp.float32)
    scores = jnp.einsum("bkgh,skh->bkgs", qg, k) / jnp.sqrt(1.0 * hd)
    owner, lpos = log_meta[:, 0], log_meta[:, 1]
    valid = (owner[None] == req_ids[:, None]) & (owner[None] >= 0) & (
        req_ids[:, None] >= 0
    )
    valid = valid & (lpos[None] < lengths[:, None]) & (lpos[None] >= 0)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,skh->bkgh", p, log_v.astype(jnp.float32))
    return out, m, l  # out is UN-normalized (sum of p*v)


def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    log_k: Optional[jax.Array] = None,
    log_v: Optional[jax.Array] = None,
    log_meta: Optional[jax.Array] = None,
    page_lengths: Optional[jax.Array] = None,
    req_ids: Optional[jax.Array] = None,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """(B, H, hd) attention output over pages (+ log).

    ``page_lengths`` (default = lengths): per-request compaction watermark —
    page entries are valid only below it; positions at/above it live in the
    write log. This is the disjointness invariant the runtime maintains
    (the paper's "log holds the newest data until compaction").
    ``req_ids`` (default arange(B)): the request each batch row serves —
    log entries are owned by request id, not batch position.
    """
    if page_lengths is None:
        page_lengths = lengths
    if req_ids is None:
        req_ids = jnp.arange(q.shape[0], dtype=jnp.int32)
    if not use_pallas:
        return paged_decode_attention_ref(
            q, k_pages, v_pages, page_table, lengths, log_k, log_v, log_meta,
            page_lengths=page_lengths, req_ids=req_ids,
        )
    B, H, hd = q.shape
    KV = k_pages.shape[2]
    g = H // KV
    out_p, m_p, l_p = paged_decode_attention_pallas(
        q, k_pages, v_pages, page_table, page_lengths, interpret=interpret
    )
    if log_k is None:
        return out_p
    out_l, m_l, l_l = _log_attention(q, log_k, log_v, log_meta, lengths, req_ids)
    # flash-decoding combine: pages output is normalized, log's is not
    out_pg = out_p.reshape(B, KV, g, hd).astype(jnp.float32)
    m = jnp.maximum(m_p, m_l)
    a_p = jnp.exp(m_p - m) * l_p
    a_l = jnp.exp(m_l - m)
    denom = a_p + a_l * l_l
    denom = jnp.maximum(denom, 1e-30)
    out = (out_pg * a_p + out_l * a_l) / denom
    return out.reshape(B, H, hd).astype(q.dtype)
