"""Pure-jnp oracle for paged decode attention with write-log merge.

Semantics (one decode step, GQA):
  q:          (B, H, hd)
  k_pages:    (P, page, KV, hd)  HBM page pool (shared across requests)
  v_pages:    (P, page, KV, hd)
  page_table: (B, N) int32 — page-pool slot of request b's n-th logical
              page; -1 = not resident (masked; the serving scheduler
              guarantees residency for scheduled requests)
  lengths:    (B,) int32 — valid tokens per request
  log_k/v:    (S, KV, hd) — token-granular write log (ring)
  log_meta:   (S, 2) int32 — (request, abs_pos) per slot; request = -1 empty

A logical position covered by BOTH a page and a log entry takes the LOG
value (newest-wins: the log holds tokens not yet compacted into pages).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    log_k: Optional[jax.Array] = None,
    log_v: Optional[jax.Array] = None,
    log_meta: Optional[jax.Array] = None,
    page_lengths: Optional[jax.Array] = None,
    req_ids: Optional[jax.Array] = None,
) -> jax.Array:
    B, H, hd = q.shape
    P, page, KV, _ = k_pages.shape
    N = page_table.shape[1]
    g = H // KV
    if page_lengths is None:
        page_lengths = lengths
    if req_ids is None:
        req_ids = jnp.arange(B, dtype=jnp.int32)  # batch row b serves request b

    safe_table = jnp.maximum(page_table, 0)
    k = k_pages[safe_table]  # (B, N, page, KV, hd)
    v = v_pages[safe_table]
    k = k.reshape(B, N * page, KV, hd)
    v = v.reshape(B, N * page, KV, hd)
    pos = jnp.arange(N * page)[None]  # (1, S_pages)
    resident = jnp.repeat(page_table >= 0, page, axis=1)  # (B, N*page)
    valid = (pos < page_lengths[:, None]) & resident

    if log_k is not None:
        S = log_k.shape[0]
        owner = log_meta[:, 0]  # (S,)
        lpos = log_meta[:, 1]
        # mask page entries shadowed by a log entry for the same (req, pos)
        shadow = jnp.zeros((B, N * page), bool)
        match = (owner[None, :] == req_ids[:, None]) & (owner[None, :] >= 0) & (
            req_ids[:, None] >= 0
        )
        # for each request: mark positions present in the log
        onehot = jnp.where(
            match, jnp.where(lpos[None, :] >= 0, lpos[None, :], N * page), N * page
        )  # (B, S) -> position or sentinel
        shadow = jax.vmap(
            lambda oh: jnp.zeros((N * page + 1,), bool).at[oh].set(True)[:-1]
        )(onehot)
        valid = valid & ~shadow
        log_valid = match & (lpos[None, :] < lengths[:, None]) & (lpos[None, :] >= 0)
        k = jnp.concatenate([k, jnp.broadcast_to(log_k[None], (B, S, KV, hd))], 1)
        v = jnp.concatenate([v, jnp.broadcast_to(log_v[None], (B, S, KV, hd))], 1)
        valid = jnp.concatenate([valid, log_valid], 1)

    qg = q.reshape(B, KV, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    # value contraction follows layers.decode_attention to the letter
    # (weights rounded to the cache dtype first): the tiered engine's greedy
    # decode must be token-identical to dense decode, so the two paths must
    # share one arithmetic recipe, not just one math.
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(v.dtype), v)
    return out.reshape(B, H, hd).astype(q.dtype)
