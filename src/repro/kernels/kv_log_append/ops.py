"""Dispatch wrapper for the KV log append kernel."""
from __future__ import annotations

import jax

from repro.kernels.kv_log_append.kernel import kv_log_append_pallas
from repro.kernels.kv_log_append.ref import kv_log_append_ref


def kv_log_append(
    log_k, log_v, log_meta, tail, k_new, v_new, req_ids, positions,
    *, use_pallas: bool = True, interpret: bool = True,
):
    if not use_pallas:
        return kv_log_append_ref(
            log_k, log_v, log_meta, tail, k_new, v_new, req_ids, positions
        )
    return kv_log_append_pallas(
        log_k, log_v, log_meta, tail, k_new, v_new, req_ids, positions,
        interpret=interpret,
    )
