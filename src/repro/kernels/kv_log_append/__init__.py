from repro.kernels.kv_log_append.ops import kv_log_append  # noqa: F401
