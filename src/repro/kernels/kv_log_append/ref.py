"""Pure-jnp oracle: append a batch of decode tokens into the KV write log.

log_k/log_v: (L, S, KV, hd) ring buffers (all layers), log_meta: (S, 2)
(request, abs_pos), tail: () int32. Appends B tokens (one per request in
``req_ids`` at position ``positions``) contiguously at the tail. The
caller guarantees tail + B <= S (the engine compacts before overflow —
the paper's double-buffered swap).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def kv_log_append_ref(
    log_k: jax.Array,  # (L, S, KV, hd)
    log_v: jax.Array,
    log_meta: jax.Array,  # (S, 2) int32
    tail: jax.Array,  # () int32
    k_new: jax.Array,  # (L, B, KV, hd)
    v_new: jax.Array,
    req_ids: jax.Array,  # (B,) int32
    positions: jax.Array,  # (B,) int32
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    B = k_new.shape[1]
    log_k = jax.lax.dynamic_update_slice_in_dim(log_k, k_new, tail, axis=1)
    log_v = jax.lax.dynamic_update_slice_in_dim(log_v, v_new, tail, axis=1)
    meta_new = jnp.stack([req_ids, positions], axis=-1)
    log_meta = jax.lax.dynamic_update_slice_in_dim(log_meta, meta_new, tail, axis=0)
    return log_k, log_v, log_meta, tail + B
