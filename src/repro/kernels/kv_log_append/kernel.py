"""Pallas TPU kernel: KV write-log append (decode write path).

Grid over layers; the whole per-layer log block stays in ANY/HBM-resident
ref and the B new tokens are stored at the (scalar-prefetched) tail with a
dynamic slice — on TPU this is a single VMEM->HBM DMA per layer, no
read-modify-write of the surrounding log (the paper's cacheline append:
no page fetch on the critical write path). Aliased in/out for in-place
update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tail_ref, knew_ref, vnew_ref, logk_ref, logv_ref, logk_out, logv_out):
    # in/out aliased: only the appended rows are written
    tail = tail_ref[0]
    B = knew_ref.shape[1]
    logk_out[0, pl.dslice(tail, B)] = knew_ref[0]
    logv_out[0, pl.dslice(tail, B)] = vnew_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_log_append_pallas(
    log_k: jax.Array,  # (L, S, KV, hd)
    log_v: jax.Array,
    log_meta: jax.Array,  # (S, 2)
    tail: jax.Array,  # ()
    k_new: jax.Array,  # (L, B, KV, hd)
    v_new: jax.Array,
    req_ids: jax.Array,
    positions: jax.Array,
    *,
    interpret: bool = True,
):
    L, S, KV, hd = log_k.shape
    B = k_new.shape[1]
    tail_arr = jnp.reshape(tail, (1,)).astype(jnp.int32)

    new_k, new_v = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L,),
            in_specs=[
                pl.BlockSpec((1, B, KV, hd), lambda l, t: (l, 0, 0, 0)),
                pl.BlockSpec((1, B, KV, hd), lambda l, t: (l, 0, 0, 0)),
                pl.BlockSpec((1, S, KV, hd), lambda l, t: (l, 0, 0, 0)),
                pl.BlockSpec((1, S, KV, hd), lambda l, t: (l, 0, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, S, KV, hd), lambda l, t: (l, 0, 0, 0)),
                pl.BlockSpec((1, S, KV, hd), lambda l, t: (l, 0, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(log_k.shape, log_k.dtype),
            jax.ShapeDtypeStruct(log_v.shape, log_v.dtype),
        ],
        input_output_aliases={3: 0, 4: 1},  # log_k/log_v aliased (in-place)
        interpret=interpret,
    )(tail_arr, k_new, v_new, log_k, log_v)

    meta_new = jnp.stack([req_ids, positions], axis=-1)
    log_meta = jax.lax.dynamic_update_slice_in_dim(log_meta, meta_new, tail, axis=0)
    return new_k, new_v, log_meta, tail + B
