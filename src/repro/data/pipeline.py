"""Deterministic synthetic LM data pipeline.

Production posture: the pipeline is a pure function of (seed, step) so that
(1) every data-parallel host can generate exactly its own shard without
coordination, and (2) restarts resume bit-identically from the checkpointed
``DataState`` — the data side of fault tolerance. A double-buffered
prefetch thread overlaps host generation with device steps.

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, so models actually reduce loss on it (useful for the
end-to-end training example) while staying fully offline.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d) -> "DataState":
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0,
                 motif_len: int = 16, n_motifs: int = 64):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.state = DataState(seed, 0)
        base = np.random.default_rng(seed)
        # fixed motif table (part of the "dataset", derived from seed)
        self.motifs = base.integers(0, vocab, size=(n_motifs, motif_len))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step) — restart-safe, host-shardable."""
        rng = np.random.default_rng((self.state.seed << 20) ^ step)
        B, S = self.global_batch, self.seq_len
        zipf = rng.zipf(1.3, size=(B, S)) % self.vocab
        toks = zipf.astype(np.int32)
        # overlay motifs (predictable structure -> learnable signal)
        n_over = S // self.motifs.shape[1] // 2
        for b in range(B):
            ids = rng.integers(0, len(self.motifs), size=n_over)
            starts = rng.integers(0, S - self.motifs.shape[1], size=n_over)
            for m, s0 in zip(ids, starts):
                toks[b, s0 : s0 + self.motifs.shape[1]] = self.motifs[m]
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.state.step)
            self.state.step += 1


def make_pipeline(vocab: int, seq_len: int, global_batch: int, seed: int = 0,
                  prefetch: int = 2):
    """Returns (source, iterator-with-prefetch)."""
    src = SyntheticLM(vocab, seq_len, global_batch, seed)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    it = iter(src)

    def worker():
        for b in it:
            q.put(b)

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        while True:
            yield q.get()

    return src, gen()
