"""Fig 23: alternative page-migration mechanisms. Paper: SkyByte-CP >
AstriFlash-CXL (1.09x avg) > SkyByte-CT (TPP sampling); the write log
stacks on top of TPP too (SkyByte-WCT)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, WORKLOADS, cached_sim, print_csv

DESIGNS = (
    ("skybyte-c", "skybyte", "SkyByte-C"),
    ("skybyte-cp", "skybyte", "SkyByte-CP"),
    ("skybyte-cp", "tpp", "SkyByte-CT"),
    ("skybyte-full", "tpp", "SkyByte-WCT"),
    ("skybyte-cp", "astriflash", "AstriFlash-CXL"),
    ("skybyte-full", "skybyte", "SkyByte-Full"),
)


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        ref = None
        for variant, policy, label in DESIGNS:
            cfg = dataclasses.replace(SimConfig(), promo_policy=policy)
            r = cached_sim(wl, variant, cfg=cfg, total_req=total_req, force=force)
            if ref is None:
                ref = r
            rows.append({
                "workload": wl, "design": label,
                "exec_ms": round(r["exec_ns"] / 1e6, 3),
                "norm_vs_SkyByte-C": round(r["exec_ns"] / ref["exec_ns"], 4),
                "promotions": r["promotions"], "demotions": r["demotions"],
            })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig23_migration (CP > AstriFlash > CT; W stacks on TPP)",
              rows, ["workload", "design", "exec_ms", "norm_vs_SkyByte-C",
                     "promotions", "demotions"])
    return rows


if __name__ == "__main__":
    main()
