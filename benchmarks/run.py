"""Benchmark orchestrator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                 # full suite
  PYTHONPATH=src python -m benchmarks.run --quick         # reduced req counts
  PYTHONPATH=src python -m benchmarks.run --jobs $(nproc) # parallel grid
  PYTHONPATH=src python -m benchmarks.run --only fig14,fig18
  PYTHONPATH=src python -m benchmarks.run --profile       # per-section req/s

The orchestrator first enumerates every (workload, variant, cfg) cell the
selected sections will request (via each module's cells()), dedupes them by
cache key — fig14/17/18/tab3 share one 7x8 grid — and fans the misses
across --jobs worker processes. The figure modules then render serially
from the warm cache in seconds.

Simulator results are cached in artifacts/sim/, keyed by run parameters
plus a fingerprint of the simulator sources (stale artifacts never survive
code changes; delete the directory to force a full re-run).

A machine-readable perf report is written to BENCH_sim.json: req/s of both
replay engines on a calibration cell, per-section wall clock, and suite
totals. The roofline section reads the dry-run artifacts (artifacts/dryrun/).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

from repro.configs.base import ObsConfig, SimConfig
from repro.core.simulator import ENGINES, simulate
from repro.log import get_logger

from benchmarks import (
    common,
    fig9_threshold,
    fig10_policies,
    fig14_exec_time,
    fig15_threads,
    fig17_amat,
    fig18_write_traffic,
    fig19_logsize,
    fig21_dramsize,
    fig22_flashlat,
    fig23_migration,
    fig_breakdown,
    fig_faults,
    fig_gc_tail,
    tab3_readlat,
)

# (name, module, total_req_full, total_req_quick)
SECTIONS = [
    ("fig14", fig14_exec_time, 1_500_000, 300_000),
    ("fig17", fig17_amat, 1_500_000, 300_000),
    ("fig18", fig18_write_traffic, 1_500_000, 300_000),
    ("tab3", tab3_readlat, 1_500_000, 300_000),
    ("fig9", fig9_threshold, 600_000, 200_000),
    ("fig10", fig10_policies, 600_000, 200_000),
    ("fig15", fig15_threads, 600_000, 200_000),
    ("fig19", fig19_logsize, 1_000_000, 200_000),
    ("fig21", fig21_dramsize, 600_000, 200_000),
    ("fig22", fig22_flashlat, 600_000, 200_000),
    ("fig23", fig23_migration, 600_000, 200_000),
    ("gc_tail", fig_gc_tail, 600_000, 200_000),
    ("faults", fig_faults, 600_000, 200_000),
    ("breakdown", fig_breakdown, 600_000, 200_000),
]

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_sim.json"
_LOG = get_logger(__name__)


# Calibration cells: ctx-switch-bound cells (short quanta — the regime
# the classification cache and the turbo burst walks target; tpcc/srad
# burst harder than bfs-dense, so they are the turbo engine's acceptance
# cells), the paper's headline configuration, and a boundary-free cell
# (pure vector path).
CALIBRATION_CELLS = (
    ("bfs-dense", "skybyte-c"),
    ("tpcc", "skybyte-c"),
    ("srad", "skybyte-cp"),
    ("bfs-dense", "skybyte-full"),
    ("ycsb", "dram-only"),
)


def calibrate_engines(total_req: int = 200_000) -> dict:
    """Per-cell replay throughput of both engines (req/s, CPU time — wall
    clock on shared CI boxes is steal-noisy) plus the batched engine's
    classification-cache hit/repair rates and counters."""
    from repro.core import engine as _engine

    # suspend any --engine override: the whole point is comparing both
    forced = os.environ.pop("REPRO_SIM_ENGINE", None)
    out = {}
    try:
        for workload, variant in CALIBRATION_CELLS:
            cell = {}
            for engine in ("reference", "batched"):
                cfg = dataclasses.replace(SimConfig(), engine=engine)
                t0 = time.process_time()
                r = simulate(workload, variant, cfg, total_req=total_req,
                             seed=0)
                cell[engine] = round(r["n"] / max(
                    time.process_time() - t0, 1e-9), 1)
            cell["speedup"] = round(
                cell["batched"] / max(cell["reference"], 1e-9), 2)
            cell["cache"] = dict(_engine.CACHE_STATS)
            cell["cache_hit_rate"] = round(_engine.cache_hit_rate(), 4)
            cell["cache_repair_rate"] = round(_engine.cache_repair_rate(), 4)
            # span-floor trajectory: how much of the cell ran through the
            # fused kernel vs the scalar span fallback (batched engine;
            # FUSED_STATS is reset at the start of each batched simulate)
            fstats = dict(_engine.FUSED_STATS)
            cell["span_events"] = fstats["span_events"]
            cell["fused_events"] = fstats["fused_events"]
            cell["vector_events"] = fstats["vector_events"]
            cell["fused_frac"] = round(_engine.fused_fraction(r["n"]), 4)
            cell["events_per_sec"] = cell["batched"]
            # turbo engine on the same cell: throughput next to the exact
            # engines plus its exported drift bound (info-only in
            # bench_diff; the hard acceptance runs through
            # scripts/paired_bench.py --engines batched,turbo)
            from repro.core import turbo as _turbo

            cfg_t = dataclasses.replace(SimConfig(), engine="turbo")
            t0 = time.process_time()
            rt = simulate(workload, variant, cfg_t, total_req=total_req,
                          seed=0)
            t_reqps = round(rt["n"] / max(time.process_time() - t0, 1e-9), 1)
            cell["turbo"] = {
                "events_per_sec": t_reqps,
                "speedup_vs_batched": round(
                    t_reqps / max(cell["batched"], 1e-9), 2),
                "drift_max": rt.get("turbo_drift_max", 0.0),
                "drift_mean": rt.get("turbo_drift_mean", 0.0),
                "fallback": bool(_turbo.TURBO_STATS["fallbacks"]),
            }
            # latency-provenance summary for the same cell (info-only in
            # bench_diff: obs is an instrumentation layer, not a perf
            # gate). One obs-enabled run on the batched engine — obs is a
            # conflict class, so this also exercises the non-fused path.
            cfg_obs = dataclasses.replace(
                SimConfig(), engine="batched", obs=ObsConfig(enabled=True))
            ob = simulate(workload, variant, cfg_obs,
                          total_req=total_req, seed=0)["obs"]
            cell["obs"] = {
                "conservation_pass": ob["conservation"]["pass"],
                "violations": ob["conservation"]["violations"],
                "closure_fallbacks": ob["conservation"]["closure_fallbacks"],
                "n_miss": ob["n_miss"],
                "n_stall": ob["n_stall"],
                "component_p99_ns": {
                    k: v["p99_ns"] for k, v in ob["components"].items()
                    if isinstance(v, dict) and "p99_ns" in v},
            }
            out[f"{workload}/{variant}"] = cell
    finally:
        if forced is not None:
            os.environ["REPRO_SIM_ENGINE"] = forced
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--jobs", type=int, default=0,
                    help="worker processes for the simulation grid "
                         "(default 0 = one per detected PHYSICAL core; "
                         "nproc counts SMT/vCPU siblings that share "
                         "execution resources and inflate grid CPU time "
                         "for marginal wall gain)")
    ap.add_argument("--engine", default="",
                    help="force a replay engine (default: SimConfig "
                         "default; see repro.core.simulator.ENGINES)")
    ap.add_argument("--profile", action="store_true",
                    help="print per-section req/s and cache hit counts")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the engine-throughput calibration runs")
    args = ap.parse_args(argv)
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    # a typo'd section name used to run NOTHING silently; fail loudly with
    # the registered names instead
    valid = {name for name, _, _, _ in SECTIONS} | {"roofline"}
    unknown = sorted(only - valid)
    if unknown:
        ap.error(f"unknown --only section(s): {', '.join(unknown)}; "
                 f"valid sections: {', '.join(sorted(valid))}")
    # same fail-loudly contract as --only: a typo'd engine name used to
    # surface only deep inside simulate(); validate against the registry
    if args.engine and args.engine not in ENGINES:
        ap.error(f"unknown --engine: {args.engine!r}; "
                 f"valid engines: {', '.join(ENGINES)}")

    if args.jobs <= 0:
        phys = common.physical_cores()
        logical = os.cpu_count() or 1
        env_jobs = os.environ.get("REPRO_JOBS", "").strip()
        try:
            env_jobs_n = int(env_jobs) if env_jobs else 0
        except ValueError:
            print(f"# jobs: ignoring non-integer REPRO_JOBS={env_jobs!r}, "
                  f"falling back to auto-detect", flush=True)
            env_jobs_n = 0
        if env_jobs_n > 0:
            # container topology can overstate real cores (the 2-vCPU /
            # 1-host-core case); REPRO_JOBS pins the grid width without
            # editing every invocation
            args.jobs = env_jobs_n
            print(f"# jobs: REPRO_JOBS={args.jobs} override "
                  f"(detected {phys} physical / {logical} logical core(s))",
                  flush=True)
        else:
            args.jobs = phys
            print(f"# jobs auto-detect: {phys} physical core(s) "
                  f"({logical} logical; SMT/vCPU siblings excluded) "
                  f"-> --jobs {args.jobs}", flush=True)

    if args.engine:
        os.environ["REPRO_SIM_ENGINE"] = args.engine

    report = {
        "jobs": args.jobs,
        "quick": bool(args.quick),
        "code_fingerprint": common.code_fingerprint(),
        "sections": {},
    }
    t0 = time.time()

    selected = [(name, mod, quick_n if args.quick else full_n)
                for name, mod, full_n, quick_n in SECTIONS
                if not only or name in only]

    # 1) enumerate + dedupe the full grid, 2) warm it in parallel
    cells = []
    enumerated = set()
    for name, mod, n in selected:
        try:
            cells.extend(mod.cells(total_req=n))
            enumerated.add(name)
        except Exception as e:
            _LOG.warning("%s cell enumeration FAILED: %s: %s",
                         name, type(e).__name__, e)
    warm = common.warm_cache(cells, jobs=args.jobs, force=args.force)
    report["grid"] = warm
    print(f"# grid: {warm['cells_total']} cells requested, "
          f"{warm['cells_run']} simulated fresh "
          f"({warm['req'] / 1e6:.1f}M req, {warm['cpu_s']:.0f}s cpu, "
          f"{warm['wall_s']:.0f}s wall at --jobs {args.jobs})", flush=True)

    # 3) render every section from the warm cache. The warm phase already
    # force-recomputed every enumerated cell; only a section whose grid
    # could not be enumerated must carry --force itself (serial but correct).
    for name, mod, n in selected:
        t1 = time.time()
        c1 = time.process_time()
        hits0 = common.PERF["cached_hits"]
        try:
            mod.main(total_req=n, force=args.force and name not in enumerated)
            status = "ok"
        except Exception as e:  # keep the suite running
            status = f"{type(e).__name__}: {e}"
            _LOG.warning("%s FAILED: %s", name, status)
        wall = time.time() - t1
        # render cpu (process_time covers in-process cell sims too): the
        # stable signal bench_diff gates on; wall stays informational
        cpu = time.process_time() - c1
        report["sections"][name] = {
            "wall_s": round(wall, 2),
            "cpu_s": round(cpu, 2),
            "total_req": n,
            "cache_hits": common.PERF["cached_hits"] - hits0,
            "status": status,
        }
        print(f"# {name} done in {wall:.1f}s ({cpu:.1f}s cpu)\n", flush=True)

    if not args.skip_roofline and (not only or "roofline" in only):
        try:
            from benchmarks import roofline

            roofline.main()
        except Exception as e:
            _LOG.warning("roofline FAILED: %s: %s", type(e).__name__, e)

    if not args.no_calibrate:
        n_cal = 100_000 if args.quick else 300_000
        report["engine_reqps"] = calibrate_engines(n_cal)
        for cell, c in report["engine_reqps"].items():
            print(f"# engine calibration {cell} ({n_cal} req): "
                  f"reference={c['reference'] / 1e3:.0f}k/s "
                  f"batched={c['batched'] / 1e3:.0f}k/s ({c['speedup']}x, "
                  f"cache hit={c['cache_hit_rate']:.0%} "
                  f"repair={c['cache_repair_rate']:.0%}, "
                  f"obs conservation="
                  f"{'ok' if c['obs']['conservation_pass'] else 'FAIL'})")

    report["suite_wall_s"] = round(time.time() - t0, 1)
    BENCH_PATH.write_text(json.dumps(report, indent=1))
    print(f"# total {report['suite_wall_s']:.0f}s -> {BENCH_PATH.name}")

    if args.profile:
        rps = warm["req"] / max(warm["cpu_s"], 1e-9)
        print("# profile grid: "
              f"{warm['req'] / 1e6:.1f}M fresh req in {warm['cpu_s']:.0f}s cpu "
              f"/ {warm['wall_s']:.0f}s wall ({rps / 1e3:.0f}k req/s/worker), "
              f"{common.PERF['cached_hits']} cache hits on render")
        for name, sec in report["sections"].items():
            print(f"# profile {name}: {sec['wall_s']}s render, "
                  f"{sec['cache_hits']} cells")


if __name__ == "__main__":
    main()
