"""Benchmark orchestrator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced request counts
  PYTHONPATH=src python -m benchmarks.run --only fig14,fig18

Simulator results are cached in artifacts/sim/ (delete to re-run).
The roofline section reads the dry-run artifacts (artifacts/dryrun/).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig9_threshold,
    fig10_policies,
    fig14_exec_time,
    fig15_threads,
    fig17_amat,
    fig18_write_traffic,
    fig19_logsize,
    fig21_dramsize,
    fig22_flashlat,
    fig23_migration,
    tab3_readlat,
)

# (name, module, total_req_full, total_req_quick)
SECTIONS = [
    ("fig14", fig14_exec_time, 1_500_000, 300_000),
    ("fig17", fig17_amat, 1_500_000, 300_000),
    ("fig18", fig18_write_traffic, 1_500_000, 300_000),
    ("tab3", tab3_readlat, 1_500_000, 300_000),
    ("fig9", fig9_threshold, 600_000, 200_000),
    ("fig10", fig10_policies, 600_000, 200_000),
    ("fig15", fig15_threads, 600_000, 200_000),
    ("fig19", fig19_logsize, 1_000_000, 200_000),
    ("fig21", fig21_dramsize, 600_000, 200_000),
    ("fig22", fig22_flashlat, 600_000, 200_000),
    ("fig23", fig23_migration, 600_000, 200_000),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    t0 = time.time()
    for name, mod, full_n, quick_n in SECTIONS:
        if only and name not in only:
            continue
        n = quick_n if args.quick else full_n
        t1 = time.time()
        try:
            mod.main(total_req=n, force=args.force)
        except Exception as e:  # keep the suite running
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
        print(f"# {name} done in {time.time() - t1:.0f}s\n", flush=True)

    if not args.skip_roofline and (not only or "roofline" in only):
        try:
            from benchmarks import roofline

            roofline.main()
        except Exception as e:
            print(f"# roofline FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
