"""Fig 9: context-switch trigger threshold sweep (paper: 2 us — the measured
context-switch overhead — is the sweet spot; lower over-switches, higher
under-uses the hiding opportunity)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, cached_sim, print_csv

THRESHOLDS_NS = (500.0, 1_000.0, 2_000.0, 4_000.0, 8_000.0, 16_000.0)
WLS = ("bfs-dense", "srad", "tpcc", "dlrm")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:
        base = None
        for th in THRESHOLDS_NS:
            cfg = dataclasses.replace(SimConfig(), ctx_threshold_ns=th)
            r = cached_sim(wl, "skybyte-full", cfg=cfg, total_req=total_req,
                           force=force)
            if base is None:
                base = r
            rows.append({
                "workload": wl, "threshold_us": th / 1000.0,
                "exec_ms": round(r["exec_ns"] / 1e6, 3),
                "norm_exec_vs_500ns": round(r["exec_ns"] / base["exec_ns"], 4),
                "ctx_switches": r["ctx_switches"],
            })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig9_threshold (paper: 2us threshold optimal)",
              rows, ["workload", "threshold_us", "exec_ms",
                     "norm_exec_vs_500ns", "ctx_switches"])
    return rows


if __name__ == "__main__":
    main()
