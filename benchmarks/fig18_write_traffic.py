"""Fig 18: flash write traffic per variant (paper: SkyByte reduces write
traffic to flash 23.08x on average vs Base-CSSD)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import TOTAL_REQ, collect_cells, VARIANTS, WORKLOADS, cached_sim, print_csv


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        base = cached_sim(wl, "base-cssd", total_req=total_req, force=force)
        for v in VARIANTS:
            r = cached_sim(wl, v, total_req=total_req, force=force)
            rows.append({
                "workload": wl, "variant": v,
                "flash_write_MB": round(r["flash_write_bytes"] / 1e6, 3),
                "reduction_vs_base": round(
                    base["flash_write_bytes"] / max(r["flash_write_bytes"], 1), 2
                ),
                "compactions": r.get("compactions", 0),
                "coalesce_ratio": r.get("coalesce_ratio"),
                "gc_events": r["gc_events"],
                # block-FTL accounting (core/flash.py): device-level write
                # amplification and the GC-inclusive tail latency
                "waf": round(r["waf"], 3),
                "gc_migrated_pages": r["gc_migrated_pages"],
                "lat_p99_ns": round(r["lat_p99_ns"], 1),
            })
    red = [r["reduction_vs_base"] for r in rows
           if r["variant"] in ("skybyte-w", "skybyte-wp", "skybyte-full")
           and r["reduction_vs_base"] > 0]
    rows.append({"workload": "GEOMEAN(W/WP/Full)", "variant": "-",
                 "reduction_vs_base": round(float(np.exp(np.mean(np.log(red)))), 2)})
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig18_write_traffic (paper: 23.08x reduction)",
              rows, ["workload", "variant", "flash_write_MB",
                     "reduction_vs_base", "compactions", "coalesce_ratio",
                     "gc_events", "waf", "gc_migrated_pages", "lat_p99_ns"])
    return rows


if __name__ == "__main__":
    main()
