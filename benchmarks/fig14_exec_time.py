"""Fig 14: normalized end-to-end execution time of all SkyByte variants vs
Base-CSSD (paper: SkyByte-Full 6.11x mean speedup, 75% of DRAM-Only).

Since the physical-routing refactor the exec-time story carries a GC
attribution: reads queue on the die the FTL actually placed their page
on, so time spent waiting behind GC-carved die windows is accounted per
request (gc_pause_ms = summed host-observed GC wait across all threads;
gc_pause_frac normalizes by exec time — it can exceed 1 when several
threads stall on GC concurrently)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import TOTAL_REQ, collect_cells, VARIANTS, WORKLOADS, cached_sim, print_csv


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        base = cached_sim(wl, "base-cssd", total_req=total_req, force=force)
        for v in VARIANTS:
            r = cached_sim(wl, v, total_req=total_req, force=force)
            rows.append({
                "workload": wl, "variant": v,
                "exec_ms": round(r["exec_ns"] / 1e6, 3),
                "norm_exec": round(r["exec_ns"] / base["exec_ns"], 4),
                "speedup": round(base["exec_ns"] / r["exec_ns"], 3),
                "ssd_bw_util": round(r["ssd_bw_util"], 4),
                "ctx_switches": r["ctx_switches"],
                "gc_pause_ms": round(r["gc_pause_ns_total"] / 1e6, 3),
                "gc_pause_frac": round(
                    r["gc_pause_ns_total"] / max(r["exec_ns"], 1), 4),
                "gc_stalls": r["gc_stall_events"],
                "gc_suspends": r["gc_suspends"],
                "gc_pause_avoided_ms": round(
                    r["gc_pause_avoided_ns"] / 1e6, 3),
            })
    full = [r["speedup"] for r in rows if r["variant"] == "skybyte-full"]
    dram = [r["speedup"] for r in rows if r["variant"] == "dram-only"]
    fd = [f / d for f, d in zip(full, dram)]
    rows.append({
        "workload": "GEOMEAN", "variant": "skybyte-full",
        "speedup": round(float(np.exp(np.mean(np.log(full)))), 3),
    })
    rows.append({
        "workload": "GEOMEAN", "variant": "full-vs-dram-frac",
        "speedup": round(float(np.exp(np.mean(np.log(fd)))), 3),
    })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig14_exec_time (paper: Full=6.11x geomean, 75% of DRAM-Only)",
              rows, ["workload", "variant", "exec_ms", "norm_exec", "speedup",
                     "ssd_bw_util", "ctx_switches", "gc_pause_ms",
                     "gc_pause_frac", "gc_stalls", "gc_suspends",
                     "gc_pause_avoided_ms"])
    return rows


if __name__ == "__main__":
    main()
