"""Fig 17: average memory access time (AMAT) and its breakdown.
Paper: SkyByte-Full reduces AMAT 14.19x vs Base-CSSD; remains 1.39x of
DRAM-Only while end-to-end perf is within 1.33x."""
from __future__ import annotations

import numpy as np

from benchmarks.common import TOTAL_REQ, collect_cells, VARIANTS, WORKLOADS, cached_sim, print_csv


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        base = cached_sim(wl, "base-cssd", total_req=total_req, force=force)
        for v in VARIANTS:
            r = cached_sim(wl, v, total_req=total_req, force=force)
            n = max(r["n"], 1)
            rows.append({
                "workload": wl, "variant": v,
                "amat_ns": round(r["amat_ns"], 1),
                "amat_vs_base": round(base["amat_ns"] / r["amat_ns"], 3),
                "host_frac": round((r["host_r"] + r["host_w"]) / n, 4),
                "ssd_hit_frac": round((r["hit_log"] + r["hit_cache"] + r["ssd_w"]) / n, 4),
                "flash_frac": round(r["miss_flash"] / n, 4),
                "lat_host_frac": round(r["lat_host"] / max(r["lat_sum"], 1), 4),
                "lat_hit_frac": round(r["lat_hit"] / max(r["lat_sum"], 1), 4),
                "lat_flash_frac": round(r["lat_miss"] / max(r["lat_sum"], 1), 4),
            })
    red = [r["amat_vs_base"] for r in rows if r["variant"] == "skybyte-full"]
    rows.append({"workload": "GEOMEAN", "variant": "skybyte-full",
                 "amat_vs_base": round(float(np.exp(np.mean(np.log(red)))), 3)})
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig17_amat (paper: Full reduces AMAT 14.19x)",
              rows, ["workload", "variant", "amat_ns", "amat_vs_base",
                     "host_frac", "ssd_hit_frac", "flash_frac",
                     "lat_host_frac", "lat_hit_frac", "lat_flash_frac"])
    return rows


if __name__ == "__main__":
    main()
