"""Fig 19/20: write-log size sweep at fixed total SSD DRAM (512MB scaled).
Paper: a small log (<=64MB, 1/8 of SSD DRAM) already provides a sufficient
coalescing window; benefit tracks reducible flash write traffic."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, cached_sim, print_csv

LOG_MB = (16, 32, 64, 128, 256)  # at scale=1; scaled down by cfg.scale
WLS = ("bc", "srad", "tpcc", "dlrm")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:
        ref = None
        for mb in LOG_MB:
            cfg = dataclasses.replace(SimConfig(), write_log_bytes=mb << 20)
            r = cached_sim(wl, "skybyte-full", cfg=cfg, total_req=total_req,
                           force=force)
            if ref is None:
                ref = r
            rows.append({
                "workload": wl, "log_MB": mb,
                "exec_ms": round(r["exec_ns"] / 1e6, 3),
                "norm_exec": round(r["exec_ns"] / ref["exec_ns"], 4),
                "flash_write_MB": round(r["flash_write_bytes"] / 1e6, 3),
                "compactions": r.get("compactions", 0),
            })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig19_20_logsize (paper: 64MB log ~ enough)",
              rows, ["workload", "log_MB", "exec_ms", "norm_exec",
                     "flash_write_MB", "compactions"])
    return rows


if __name__ == "__main__":
    main()
