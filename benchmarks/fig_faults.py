"""Reliability figure (beyond-paper): fault-rate x variant sweep on the
deterministic device fault model (core/faults.py).

The paper's durability story — the cacheline write log persists across
power loss — is asserted but never priced. This section quantifies three
fault regimes and what the SkyByte mechanisms do under them:

  * ``rate`` rows — per-read first-sense error rate sweep (the ECC
    read-retry ladder): retry traffic, uncorrectable reads (UBER), and
    the request latency tail. Retries extend die busy time, so read-heavy
    workloads see the ladder directly in p99.
  * ``crash`` rows — scheduled power-loss events: write-log replay volume
    (durable lines re-programmed), dirty page-cache lines lost (what a
    log-less variant gives up), and the recovery tail (max recovery time;
    the triggering read's latency IS the host-visible outage).
  * ``diefail`` rows — a whole-die hard failure mid-run: bad-block count,
    valid pages remapped through the spare pool, and whether the device
    ended degraded (read-only) — the graceful-degradation path.

All fault draws are counter-hashed from (fault_seed, flash-read ordinal),
so every cell is exactly reproducible and engine-independent (parity
suites run with faults on; see DESIGN.md).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FaultConfig, SimConfig

from benchmarks.common import cached_sim, collect_cells, print_csv

TOTAL_REQ = 600_000
# one read-heavy profile (the retry ladder prices into p99 directly) and
# one write-heavy profile (log replay + GC interaction after a crash)
WLS = ("bfs-dense", "srad")
VARIANTS = ("base-cssd", "skybyte-full")
# 0.0 anchors the healthy device (dedupes into the main grid's cells);
# 1e-3..3e-2 spans "aging" to "end-of-life" first-sense failure rates
ERROR_RATES = (0.0, 1e-3, 1e-2, 3e-2)
# crash points in flash-read ordinals: early (cold cache, small log) and
# warmed-up (replay volume shows the durability cost). Kept low enough
# that even the most cache-friendly cell (srad/skybyte-full barely
# misses: ~2k flash reads at --quick) still reaches the second point.
CRASH_POINTS = (500, 2_000)
DIE_FAIL_AT = 500


def _row(wl, v, r, **extra):
    row = {
        "workload": wl, "variant": v, "sweep": "",
        "error_rate": "", "crash_at": "", "die_fail_at": "",
        "retry_reads": r.get("retry_reads", 0),
        "uncorrectable": r.get("uncorrectable_reads", 0),
        "uber": f"{r.get('uber', 0.0):.2e}",
        "power_losses": r.get("power_loss_events", 0),
        "replayed_pages": r.get("replayed_pages", 0),
        "lost_dirty_pages": r.get("lost_dirty_pages", 0),
        "recovery_ms": round(r.get("recovery_ns_max", 0.0) / 1e6, 3),
        "die_failures": r.get("die_failures", 0),
        "bad_blocks": r.get("bad_blocks", 0),
        "remapped_pages": r.get("remapped_pages", 0),
        "degraded": r.get("degraded_mode", 0),
        "lat_p50_ns": round(r["lat_p50_ns"], 1),
        "lat_p99_ns": round(r["lat_p99_ns"], 1),
    }
    row.update(extra)
    return row


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:  # --- read-retry ladder: error-rate sweep ---
        for v in VARIANTS:
            for rate in ERROR_RATES:
                cfg = dataclasses.replace(
                    SimConfig(), fault=FaultConfig(read_error_rate=rate))
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                               force=force)
                rows.append(_row(wl, v, r, sweep="rate", error_rate=rate))
    for wl in WLS:  # --- power loss: write-log replay + recovery tail ---
        for v in VARIANTS:
            for crash in CRASH_POINTS:
                cfg = dataclasses.replace(
                    SimConfig(), fault=FaultConfig(power_loss_at=(crash,)))
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                               force=force)
                rows.append(_row(wl, v, r, sweep="crash", crash_at=crash))
    for wl in WLS:  # --- whole-die hard failure: remap through spares ---
        for v in VARIANTS:
            cfg = dataclasses.replace(
                SimConfig(), fault=FaultConfig(die_fail_at=(DIE_FAIL_AT,)))
            r = cached_sim(wl, v, cfg=cfg, total_req=total_req, force=force)
            rows.append(_row(wl, v, r, sweep="diefail",
                             die_fail_at=DIE_FAIL_AT))
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig_faults (fault model: read-retry ladder rate sweep, "
              "power-loss replay/recovery, die failure + degradation)",
              rows, ["workload", "variant", "sweep", "error_rate",
                     "crash_at", "die_fail_at", "retry_reads",
                     "uncorrectable", "uber", "power_losses",
                     "replayed_pages", "lost_dirty_pages", "recovery_ms",
                     "die_failures", "bad_blocks", "remapped_pages",
                     "degraded", "lat_p50_ns", "lat_p99_ns"])
    return rows


if __name__ == "__main__":
    main()
