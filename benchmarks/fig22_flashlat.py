"""Fig 22: flash latency classes (ULL/ULL2/SLC/MLC, Table IV). Paper: the
write log + context switch win grows with flash latency; with enough
threads, cheap slow flash approaches expensive fast flash."""
from __future__ import annotations

import dataclasses

from repro.configs.base import FLASH_CLASSES, SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, cached_sim, print_csv

WLS = ("bfs-dense", "srad", "tpcc", "dlrm")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:
        for cls, flash in FLASH_CLASSES.items():
            cfg = dataclasses.replace(SimConfig(), flash=flash)
            base = cached_sim(wl, "skybyte-p", cfg=cfg, total_req=total_req,
                              force=force)
            for v, nt in (("skybyte-wp", 0), ("skybyte-full", 16),
                          ("skybyte-full", 24), ("skybyte-full", 32)):
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                               n_threads=nt, force=force)
                rows.append({
                    "workload": wl, "flash": cls,
                    "variant": v + (f"-{nt}" if nt else ""),
                    "exec_ms": round(r["exec_ns"] / 1e6, 3),
                    "speedup_vs_P": round(base["exec_ns"] / r["exec_ns"], 3),
                })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig22_flashlat (win grows with flash latency)",
              rows, ["workload", "flash", "variant", "exec_ms", "speedup_vs_P"])
    return rows


if __name__ == "__main__":
    main()
