"""Table III: average flash read latency of SkyByte-WP per workload
(paper: 3.3-25.7 us depending on compaction/GC interference)."""
from __future__ import annotations

from benchmarks.common import TOTAL_REQ, collect_cells, WORKLOADS, cached_sim, print_csv


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        r = cached_sim(wl, "skybyte-wp", total_req=total_req, force=force)
        lat = r["lat_miss"] / max(r["miss_flash"], 1)
        rows.append({
            "workload": wl,
            "avg_flash_read_us": round(lat / 1000.0, 2),
            "flash_reads_frac": round(r["miss_flash"] / max(r["n"], 1), 4),
        })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("tab3_readlat (paper: 3.3-25.7us)",
              rows, ["workload", "avg_flash_read_us", "flash_reads_frac"])
    return rows


if __name__ == "__main__":
    main()
