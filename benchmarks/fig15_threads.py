"""Fig 15: SkyByte-Full throughput + SSD bandwidth utilization vs thread
count (8 cores). Paper: throughput scales with threads while flash reads
dominate; flattens when context-switch overhead ~ flash latency."""
from __future__ import annotations

from benchmarks.common import TOTAL_REQ, collect_cells, WORKLOADS, cached_sim, print_csv

THREADS = (8, 16, 24, 32, 48)


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        ref = None
        for nt in THREADS:
            r = cached_sim(wl, "skybyte-full", total_req=total_req,
                           n_threads=nt, force=force)
            if ref is None:
                ref = r
            rows.append({
                "workload": wl, "threads": nt,
                "throughput_rps": round(r["throughput_rps"], 0),
                "norm_throughput": round(
                    r["throughput_rps"] / ref["throughput_rps"], 3),
                "ssd_bw_util": round(r["ssd_bw_util"], 4),
                "ctx_switches": r["ctx_switches"],
            })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig15_threads (throughput scaling with thread count)",
              rows, ["workload", "threads", "throughput_rps",
                     "norm_throughput", "ssd_bw_util", "ctx_switches"])
    return rows


if __name__ == "__main__":
    main()
