"""Latency-breakdown component stack per (workload, variant).

The paper's Fig. 17 tells the AMAT story as one bar per design point;
this section re-tells it with the PR's latency-provenance layer: every
host-visible completion is decomposed into additive nanosecond
components (die queue, GC pause/suspend, recovery barrier, outage,
flash sense, retry ladder, bus wait, transfer, write stall, plus the
constant CXL/index/DRAM terms), and each cell's stack is the per-access
mean of those components — the columns sum to (almost exactly) the
cell's AMAT, the residual being only coordinated-context-switch
overhead, which is charged to the timeline but not to any single
access. Cells run obs-enabled on the batched engine (obs is a conflict
class: this grid also keeps the non-fused scheduler path honest).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ObsConfig, SimConfig

from benchmarks.common import (TOTAL_REQ, VARIANTS, WORKLOADS, cached_sim,
                               collect_cells, print_csv)

# stacked columns, in physical order along a request's path
STACK = ("queue", "gc_pause", "gc_suspend", "recovery", "outage", "sense",
         "retry", "bus_wait", "transfer", "wstall", "cxl", "cache_index",
         "log_index", "ssd_dram", "host_dram")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    cfg = dataclasses.replace(SimConfig(), obs=ObsConfig(enabled=True))
    rows = []
    for wl in WORKLOADS:
        for v in VARIANTS:
            r = cached_sim(wl, v, cfg=cfg, total_req=total_req, force=force)
            ob = r.get("obs")
            comps = ob["components"] if isinstance(ob, dict) else {}
            n = max(r["n"], 1)
            row = {"workload": wl, "variant": v,
                   "amat_ns": round(r["amat_ns"], 1)}
            stack = 0.0
            for name in STACK:
                t = comps.get(name, {}).get("total_ns", 0.0)
                stack += t
                row[f"{name}_ns"] = round(t / n, 1)
            # the stack covers every nanosecond the requests themselves
            # spent (conservation contract); AMAT minus the stack is the
            # ctx-switch overhead the scheduler charged to the timeline
            row["stack_ns"] = round(stack / n, 1)
            if isinstance(ob, dict):
                row["conservation"] = \
                    "ok" if ob["conservation"]["pass"] else "FAIL"
                row["miss_p99_queue_ns"] = \
                    round(comps["queue"]["p99_ns"], 1)
                row["miss_p99_gc_pause_ns"] = \
                    round(comps["gc_pause"]["p99_ns"], 1)
                row["miss_p99_bus_wait_ns"] = \
                    round(comps["bus_wait"]["p99_ns"], 1)
            rows.append(row)
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig_breakdown (per-access component stack, ns; "
              "stack_ns ~= amat_ns minus ctx overhead)",
              rows,
              ["workload", "variant", "amat_ns", "stack_ns"]
              + [f"{name}_ns" for name in STACK]
              + ["miss_p99_queue_ns", "miss_p99_gc_pause_ns",
                 "miss_p99_bus_wait_ns", "conservation"])
    return rows


if __name__ == "__main__":
    main()
