"""Fig 10: thread scheduling policy comparison (RR / RANDOM / CFS).
Paper: the three policies deliver similar performance; CFS is the default."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, WORKLOADS, cached_sim, print_csv


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WORKLOADS:
        ref = None
        for pol in ("RR", "RANDOM", "CFS"):
            cfg = dataclasses.replace(SimConfig(), sched_policy=pol)
            r = cached_sim(wl, "skybyte-full", cfg=cfg, total_req=total_req,
                           force=force)
            if ref is None:
                ref = r
            rows.append({
                "workload": wl, "policy": pol,
                "exec_ms": round(r["exec_ns"] / 1e6, 3),
                "norm_exec": round(r["exec_ns"] / ref["exec_ns"], 4),
                "ctx_switches": r["ctx_switches"],
            })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig10_policies (paper: RR/RANDOM/CFS similar)",
              rows, ["workload", "policy", "exec_ms", "norm_exec",
                     "ctx_switches"])
    return rows


if __name__ == "__main__":
    main()
