"""Shared benchmark harness: cached simulator runs + CSV emission + the
process-parallel grid orchestrator.

Every figure module exposes:
  run(total_req, force) -> list[dict]   — compute the figure's rows
  cells(total_req)      -> list[dict]   — the (workload, variant, cfg) grid
                                          it will ask cached_sim for
  main(total_req, force)                — run + print CSV

``cells`` is derived mechanically from ``run`` via collect mode: cached_sim
records every requested cell and returns a neutral stub, so the grid can be
enumerated without simulating. run.py gathers all cells of the selected
sections, dedupes them by cache key (fig14/17/18/tab3 share one grid), and
fans the misses across worker processes (warm_cache); the figures then run
serially against a fully warm cache.

Results are cached under artifacts/sim/, keyed by all run parameters PLUS a
fingerprint of the simulator sources (repro/core/*.py + configs/base.py) —
editing the simulator invalidates stale artifacts automatically. The engine
choice is deliberately NOT part of the key: both engines are statistically
bit-compatible (tests/test_engine.py), so their artifacts are interchangeable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import SimConfig
from repro.core.simulator import simulate
from repro.log import get_logger

ART = Path(__file__).resolve().parent.parent / "artifacts" / "sim"
_LOG = get_logger(__name__)


def physical_cores() -> int:
    """Physical core count: unique (physical id, core id) pairs from
    /proc/cpuinfo, so SMT siblings are not double-counted the way
    ``nproc`` counts them. Falls back to os.cpu_count(). Virtualized
    containers can still overstate this (two vCPUs pinned to one host
    core report two topology cores); --jobs overrides when measured
    scaling says otherwise."""
    try:
        pairs = set()
        phys = core = None
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("physical id"):
                    phys = line.split(":", 1)[1].strip()
                elif line.startswith("core id"):
                    core = line.split(":", 1)[1].strip()
                elif not line.strip():
                    if phys is not None and core is not None:
                        pairs.add((phys, core))
                    phys = core = None
        if phys is not None and core is not None:
            pairs.add((phys, core))
        if pairs:
            return len(pairs)
    except OSError:
        pass
    return max(os.cpu_count() or 1, 1)
WORKLOADS = ("bfs-dense", "bc", "radix", "srad", "ycsb", "tpcc", "dlrm")
VARIANTS = ("base-cssd", "skybyte-c", "skybyte-p", "skybyte-w",
            "skybyte-cp", "skybyte-wp", "skybyte-full", "dram-only")
# benchmark default: long enough that every workload's write log passes
# through multiple compaction cycles (steady state)
TOTAL_REQ = 1_500_000

# perf accounting for --profile / BENCH_sim.json (per-process). The
# cls_cache_* counters aggregate the batched engine's classification-cache
# behaviour over every fresh cell this process simulates (engine.CACHE_STATS
# is reset per simulate() call, so it is drained here).
PERF = {"fresh_req": 0, "fresh_wall": 0.0, "fresh_cpu": 0.0, "cached_hits": 0,
        "cls_cache_checks": 0, "cls_cache_clean": 0, "cls_cache_repairs": 0}


def _code_fingerprint() -> str:
    """Hash of the simulator implementation: cached artifacts must not
    survive changes to the model code they were produced by."""
    import repro.configs.base as base_mod
    import repro.core as core_pkg

    h = hashlib.sha1()
    files = sorted(Path(core_pkg.__file__).parent.glob("*.py"))
    files.append(Path(base_mod.__file__))
    for f in files:
        h.update(f.name.encode())
        h.update(f.read_bytes())
    return h.hexdigest()[:12]


_FINGERPRINT: Optional[str] = None


def code_fingerprint() -> str:
    global _FINGERPRINT
    if _FINGERPRINT is None:
        _FINGERPRINT = _code_fingerprint()
    return _FINGERPRINT


def sim_key(workload: str, variant: str, cfg: SimConfig, total_req: int,
            seed: int, n_threads: int) -> Tuple[str, Path]:
    """Cache key + artifact path for one simulation cell."""
    d = dataclasses.asdict(cfg)
    d.pop("engine", None)  # engines are bit-compatible; share artifacts
    key = json.dumps(
        [workload, variant, d, total_req, seed, n_threads, code_fingerprint()],
        sort_keys=True, default=str,
    )
    h = hashlib.sha1(key.encode()).hexdigest()[:16]
    return key, ART / f"{workload}_{variant}_{h}.json"


class _CollectStub(dict):
    """Stands in for a result dict during cell collection: any missing key
    reads as 1 so ratio/geomean arithmetic in run() stays well-defined."""

    def __missing__(self, key):
        return 1

    def get(self, key, default=None):  # keep .get() consistent with []
        return 1


_COLLECTOR: Optional[List[Dict[str, Any]]] = None


def collect_cells(run_fn, total_req: int) -> List[Dict[str, Any]]:
    """Execute a figure's run() in collect mode: cached_sim records every
    requested cell instead of simulating. Returns the cell specs."""
    global _COLLECTOR
    _COLLECTOR = []
    try:
        run_fn(total_req=total_req, force=False)
    finally:
        cells, _COLLECTOR = _COLLECTOR, None
    return cells


def cached_sim(workload: str, variant: str, cfg: SimConfig = SimConfig(),
               total_req: int = TOTAL_REQ, seed: int = 0, n_threads: int = 0,
               force: bool = False) -> Dict[str, Any]:
    if _COLLECTOR is not None:
        _COLLECTOR.append(dict(workload=workload, variant=variant, cfg=cfg,
                               total_req=total_req, seed=seed,
                               n_threads=n_threads))
        return _CollectStub()
    ART.mkdir(parents=True, exist_ok=True)
    _, path = sim_key(workload, variant, cfg, total_req, seed, n_threads)
    if path.exists() and not force:
        PERF["cached_hits"] += 1
        return json.loads(path.read_text())
    t0 = time.time()
    c0 = time.process_time()
    out = simulate(workload, variant, cfg, total_req=total_req, seed=seed,
                   n_threads=n_threads)
    cpu = time.process_time() - c0
    wall = time.time() - t0
    PERF["fresh_req"] += out["n"]
    PERF["fresh_wall"] += wall
    PERF["fresh_cpu"] += cpu
    from repro.core.engine import CACHE_STATS

    PERF["cls_cache_checks"] += CACHE_STATS["checks"]
    PERF["cls_cache_clean"] += CACHE_STATS["clean"]
    PERF["cls_cache_repairs"] += CACHE_STATS["repairs"]
    out["wall_s"] = round(wall, 1)
    # per-worker CPU time: on steal-heavy shared-core boxes this is the
    # stable perf signal (wall swings +-50%); bench_diff gates on its sum
    out["cpu_s"] = round(cpu, 2)
    path.write_text(json.dumps(out, indent=1, default=float))
    return json.loads(path.read_text())


def _warm_one(spec: Dict[str, Any]) -> Tuple[str, int, float, float, str,
                                             Tuple]:
    """Worker: compute one cell into the artifact cache. Returns
    (cell name, requests simulated, cpu seconds, wall seconds, error or
    "", engine cache counters). A failing cell must not kill the suite —
    it costs only its own figures."""
    name = f"{spec['workload']}/{spec['variant']}"
    c0 = (PERF["cls_cache_checks"], PERF["cls_cache_clean"],
          PERF["cls_cache_repairs"])
    try:
        r = cached_sim(**spec)
    except Exception as e:  # noqa: BLE001 - containment boundary
        return name, 0, 0.0, 0.0, f"{type(e).__name__}: {e}", (0, 0, 0)
    cls = (PERF["cls_cache_checks"] - c0[0], PERF["cls_cache_clean"] - c0[1],
           PERF["cls_cache_repairs"] - c0[2])
    return (name, r.get("n", 0), r.get("cpu_s", 0.0), r.get("wall_s", 0.0),
            "", cls)


def dedupe_cells(cells: List[Dict[str, Any]],
                 force: bool = False) -> List[Dict[str, Any]]:
    """Drop duplicate cells (same cache key) and, unless force, cells whose
    artifact already exists."""
    seen = set()
    todo = []
    for spec in cells:
        key, path = sim_key(spec["workload"], spec["variant"], spec["cfg"],
                            spec["total_req"], spec["seed"], spec["n_threads"])
        if key in seen:
            continue
        seen.add(key)
        if path.exists() and not force:
            continue
        todo.append(spec)
    return todo


def warm_cache(cells: List[Dict[str, Any]], jobs: int = 1,
               force: bool = False, verbose: bool = True) -> Dict[str, Any]:
    """Fan the missing cells of the (workload, variant, figure) grid across
    worker processes. Returns aggregate perf numbers."""
    todo = dedupe_cells(cells, force=force)
    # cpu_s: summed per-worker process CPU (the gated signal, stable under
    # steal); wall_sum_s: summed per-cell wall (informational);
    # wall_s: the fan-out's wall clock.
    stats = {"cells_total": len(cells), "cells_run": len(todo),
             "req": 0, "cpu_s": 0.0, "wall_sum_s": 0.0, "wall_s": 0.0,
             "cls_cache_checks": 0, "cls_cache_clean": 0,
             "cls_cache_repairs": 0}
    if not todo:
        return stats
    ART.mkdir(parents=True, exist_ok=True)
    if force:  # workers skip existing artifacts; drop them up front instead
        for spec in todo:
            _, path = sim_key(spec["workload"], spec["variant"], spec["cfg"],
                              spec["total_req"], spec["seed"], spec["n_threads"])
            path.unlink(missing_ok=True)
    t0 = time.time()
    jobs = max(1, min(jobs, len(todo)))

    def record(k: int, res: Tuple, retried: bool = False) -> bool:
        """Fold one worker result into stats; True iff the cell succeeded."""
        name, req, cpu, wall, err, cls = res
        stats["req"] += req
        stats["cpu_s"] += cpu
        stats["wall_sum_s"] += wall
        stats["cls_cache_checks"] += cls[0]
        stats["cls_cache_clean"] += cls[1]
        stats["cls_cache_repairs"] += cls[2]
        tag = " on retry" if retried else ""
        if err:
            _LOG.warning("warm [%d/%d] %s FAILED%s: %s",
                         k + 1, len(todo), name, tag, err)
            return False
        if verbose:
            print(f"# warm [{k + 1}/{len(todo)}] {name}{tag} "
                  f"({cpu:.0f}s cpu / {wall:.0f}s wall)", flush=True)
        return True

    failed = []  # (index, spec) pending their one retry
    if jobs == 1:
        for k, spec in enumerate(todo):
            if not record(k, _warm_one(spec)):
                failed.append((k, spec))
    else:
        with ProcessPoolExecutor(max_workers=jobs) as ex:
            futs = {ex.submit(_warm_one, spec): (k, spec)
                    for k, spec in enumerate(todo)}
            for f in as_completed(futs):
                k, spec = futs[f]
                try:
                    res = f.result()
                except Exception as e:  # noqa: BLE001
                    # A worker that died hard (segfault, OOM kill) raises
                    # BrokenProcessPool out of EVERY pending future —
                    # containment in _warm_one never ran. Convert each to
                    # a per-cell failure instead of letting one bad cell
                    # abort the whole suite.
                    res = (f"{spec['workload']}/{spec['variant']}", 0, 0.0,
                           0.0, f"{type(e).__name__}: {e}", (0, 0, 0))
                if not record(k, res):
                    failed.append((k, spec))
    # One retry per failed cell, serial and in-process: a broken pool must
    # not take the retries down with it, and transient failures (OOM under
    # a full fan-out, a racing artifact eviction) usually pass solo.
    still_failed = []
    for k, spec in sorted(failed):
        if not record(k, _warm_one(spec), retried=True):
            still_failed.append(f"{spec['workload']}/{spec['variant']}")
    if still_failed:
        # surfaced in BENCH_sim.json via run.py's report["grid"]
        stats["failed"] = len(still_failed)
        stats["failed_cells"] = still_failed
    stats["wall_s"] = time.time() - t0
    return stats


def print_csv(name: str, rows: List[Dict[str, Any]], cols: List[str]) -> None:
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    print()
