"""Shared benchmark harness: cached simulator runs + CSV emission.

Every figure module exposes ``run(total_req, force) -> list[dict]`` and a
``main()``. Results are cached under artifacts/sim/ keyed by all run
parameters, so re-running the suite is incremental.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from pathlib import Path
from typing import Any, Dict, List

from repro.configs.base import SimConfig
from repro.core.simulator import simulate

ART = Path(__file__).resolve().parent.parent / "artifacts" / "sim"
WORKLOADS = ("bfs-dense", "bc", "radix", "srad", "ycsb", "tpcc", "dlrm")
VARIANTS = ("base-cssd", "skybyte-c", "skybyte-p", "skybyte-w",
            "skybyte-cp", "skybyte-wp", "skybyte-full", "dram-only")
# benchmark default: long enough that every workload's write log passes
# through multiple compaction cycles (steady state)
TOTAL_REQ = 1_500_000


def cached_sim(workload: str, variant: str, cfg: SimConfig = SimConfig(),
               total_req: int = TOTAL_REQ, seed: int = 0, n_threads: int = 0,
               force: bool = False) -> Dict[str, Any]:
    ART.mkdir(parents=True, exist_ok=True)
    key = json.dumps(
        [workload, variant, dataclasses.asdict(cfg), total_req, seed, n_threads],
        sort_keys=True, default=str,
    )
    h = hashlib.sha1(key.encode()).hexdigest()[:16]
    path = ART / f"{workload}_{variant}_{h}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    t0 = time.time()
    out = simulate(workload, variant, cfg, total_req=total_req, seed=seed,
                   n_threads=n_threads)
    out["wall_s"] = round(time.time() - t0, 1)
    path.write_text(json.dumps(out, indent=1, default=float))
    return json.loads(path.read_text())


def print_csv(name: str, rows: List[Dict[str, Any]], cols: List[str]) -> None:
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    print()
