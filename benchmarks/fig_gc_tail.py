"""GC / tail-latency figure (beyond-paper): over-provisioning x GC-policy
sweep on the block-granular flash backend (core/flash.py).

The paper's headline mechanisms are motivated by "unpredictable events
such as garbage collection"; this section quantifies that regime
directly. For each (workload, variant) it sweeps the physical
over-provisioning ratio and the GC victim policy and reports device
write amplification (WAF), migrated-page volume, and the request latency
tail (p50/p95/p99) — the tail is where GC-induced die-busy windows show
up, and where the coordinated context switch + write-log coalescing pay.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import collect_cells, cached_sim, print_csv

TOTAL_REQ = 600_000
# the two write-heaviest Table I profiles: GC engages across the whole
# OP sweep even at --quick request counts
WLS = ("srad", "dlrm")
VARIANTS = ("base-cssd", "skybyte-w", "skybyte-full")
OP_RATIOS = (0.03, 0.125, 0.25)
GC_POLICIES = ("greedy", "cost-benefit")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:
        for v in VARIANTS:
            for op in OP_RATIOS:
                for pol in GC_POLICIES:
                    cfg = dataclasses.replace(SimConfig(), op_ratio=op,
                                              gc_policy=pol)
                    r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                                   force=force)
                    rows.append({
                        "workload": wl, "variant": v,
                        "op_ratio": op, "gc_policy": pol,
                        "waf": round(r["waf"], 3),
                        "gc_events": r["gc_events"],
                        "gc_migrated_pages": r["gc_migrated_pages"],
                        "flash_write_MB": round(
                            r["flash_write_bytes"] / 1e6, 3),
                        "wear_max_erases": r.get("wear_max_erases", 0),
                        "lat_p50_ns": round(r["lat_p50_ns"], 1),
                        "lat_p95_ns": round(r["lat_p95_ns"], 1),
                        "lat_p99_ns": round(r["lat_p99_ns"], 1),
                    })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig_gc_tail (block FTL: over-provisioning x GC policy, "
              "WAF + latency tail)",
              rows, ["workload", "variant", "op_ratio", "gc_policy", "waf",
                     "gc_events", "gc_migrated_pages", "flash_write_MB",
                     "wear_max_erases", "lat_p50_ns", "lat_p95_ns",
                     "lat_p99_ns"])
    return rows


if __name__ == "__main__":
    main()
