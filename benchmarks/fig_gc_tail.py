"""GC / tail-latency figure (beyond-paper): over-provisioning x GC-policy
sweep plus a wear-leveling x hot/cold-frontier placement sweep on the
block-granular flash backend (core/flash.py).

The paper's headline mechanisms are motivated by "unpredictable events
such as garbage collection"; this section quantifies that regime
directly. Two sweeps:

  * ``op`` rows — for each (workload, variant) the physical
    over-provisioning ratio and the GC victim policy, reporting device
    write amplification (WAF), migrated-page volume, the request latency
    tail (p50/p95/p99) and the host-observed GC-pause attribution — with
    physical routing, the tail is where GC-induced die-busy windows show
    up, and where the coordinated context switch + write-log coalescing
    pay.
  * ``wear`` rows — at the default (GC-live) over-provisioning, the
    ``wear_leveling`` x ``hotcold`` placement-policy grid, with
    wear-spread rows (max/mean per-block erases): wear-aware free-block
    picks flatten the spread LIFO recycling concentrates; hot/cold
    frontier separation lowers migration volume by letting hot pages die
    together.
  * ``qos`` rows — the die-level QoS grid (``gc_suspend`` x
    ``read_priority`` x ``superblock``, core/qos.py) at the GC-live
    over-provisioning point, reporting READ-only percentiles
    (lat_read_p*: the mixed tail hides the read win behind the posted
    writes that absorb read-priority's backpressure), suspend/resume
    counts, avoided-pause volume, bypass counts and the max per-die
    queue wait a host read observed. Superblock striping is the
    blast-radius axis: per-die blocks confine GC to 1/1024 dies (stalls
    rare but huge), striped blocks spread each GC across every die
    (stalls dense but shallow) — suspend/resume + read priority then
    clip them.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import collect_cells, cached_sim, print_csv

TOTAL_REQ = 600_000
# the two write-heaviest Table I profiles: GC engages across the whole
# OP sweep even at --quick request counts
WLS = ("srad", "dlrm")
VARIANTS = ("base-cssd", "skybyte-w", "skybyte-full")
OP_RATIOS = (0.03, 0.125, 0.25)
GC_POLICIES = ("greedy", "cost-benefit")
# wear sweep: default OP (GC live), greedy victims, the placement grid
WEAR_VARIANTS = ("base-cssd", "skybyte-full")
WEAR_GRID = ((False, False), (True, False), (False, True), (True, True))
# qos sweep: GC-live OP, (gc_suspend, read_priority, superblock) cells.
# base-cssd gets the full ablation (each mechanism alone, both, and the
# superblock axis off/on); skybyte-full just off / all-on — its write
# log + coordinated switching already blunt the write-path tail, the
# read-side QoS story is the base-CSSD one
QOS_OP = 0.03
QOS_GRID = ((False, False, False), (True, False, False),
            (False, True, False), (True, True, False),
            (False, False, True), (True, True, True))
QOS_GRID_SKY = ((False, False, False), (True, True, False),
                (True, True, True))


def _row(wl, v, r, **extra):
    wear_mean = r.get("wear_mean_erases", 0)
    row = {
        "workload": wl, "variant": v,
        "op_ratio": "", "gc_policy": "",
        "wear_leveling": "", "hotcold": "",
        "gc_suspend": "", "read_priority": "", "superblock": "",
        "waf": round(r["waf"], 3),
        "gc_events": r["gc_events"],
        "gc_migrated_pages": r["gc_migrated_pages"],
        "flash_write_MB": round(r["flash_write_bytes"] / 1e6, 3),
        "wear_max_erases": r.get("wear_max_erases", 0),
        "wear_spread": round(r.get("wear_max_erases", 0) / wear_mean, 2)
        if wear_mean else 0.0,
        "gc_pause_ms": round(r["gc_pause_ns_total"] / 1e6, 3),
        "lat_p50_ns": round(r["lat_p50_ns"], 1),
        "lat_p95_ns": round(r["lat_p95_ns"], 1),
        "lat_p99_ns": round(r["lat_p99_ns"], 1),
    }
    row.update(extra)
    return row


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:  # --- over-provisioning x victim policy ---
        for v in VARIANTS:
            for op in OP_RATIOS:
                for pol in GC_POLICIES:
                    cfg = dataclasses.replace(SimConfig(), op_ratio=op,
                                              gc_policy=pol)
                    r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                                   force=force)
                    rows.append(_row(wl, v, r, op_ratio=op, gc_policy=pol))
    for wl in WLS:  # --- wear_leveling x hotcold placement grid ---
        for v in WEAR_VARIANTS:
            for wear, hc in WEAR_GRID:
                cfg = dataclasses.replace(SimConfig(), wear_leveling=wear,
                                          hotcold=hc)
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                               force=force)
                rows.append(_row(wl, v, r, op_ratio=cfg.op_ratio,
                                gc_policy=cfg.gc_policy,
                                wear_leveling=int(wear), hotcold=int(hc)))
    for wl in WLS:  # --- die-level QoS grid ---
        for v, grid in (("base-cssd", QOS_GRID),
                        ("skybyte-full", QOS_GRID_SKY)):
            for susp, rp, sb in grid:
                cfg = dataclasses.replace(
                    SimConfig(), op_ratio=QOS_OP, gc_suspend=susp,
                    read_priority=rp, superblock=sb)
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req,
                               force=force)
                rows.append(_row(
                    wl, v, r, op_ratio=QOS_OP,
                    gc_suspend=int(susp), read_priority=int(rp),
                    superblock=int(sb),
                    lat_read_p50_ns=round(r["lat_read_p50_ns"], 1),
                    lat_read_p95_ns=round(r["lat_read_p95_ns"], 1),
                    lat_read_p99_ns=round(r["lat_read_p99_ns"], 1),
                    gc_suspends=r["gc_suspends"],
                    gc_resumes=r["gc_resumes"],
                    gc_resume_ms=round(r["gc_resume_ns_total"] / 1e6, 3),
                    gc_pause_avoided_ms=round(
                        r["gc_pause_avoided_ns"] / 1e6, 3),
                    rp_bypasses=r["rp_bypasses"],
                    die_wait_max_us=round(
                        r["qos_die_wait_max_ns"] / 1e3, 1)))
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig_gc_tail (block FTL: over-provisioning x GC policy + "
              "wear_leveling x hotcold + die-level QoS grid, WAF + wear "
              "spread + latency tail)",
              rows, ["workload", "variant", "op_ratio", "gc_policy",
                     "wear_leveling", "hotcold", "gc_suspend",
                     "read_priority", "superblock", "waf", "gc_events",
                     "gc_migrated_pages", "flash_write_MB",
                     "wear_max_erases", "wear_spread", "gc_pause_ms",
                     "lat_p50_ns", "lat_p95_ns", "lat_p99_ns",
                     "lat_read_p50_ns", "lat_read_p95_ns",
                     "lat_read_p99_ns", "gc_suspends", "gc_resumes",
                     "gc_resume_ms", "gc_pause_avoided_ms",
                     "rp_bypasses", "die_wait_max_us"])
    return rows


if __name__ == "__main__":
    main()
