"""Fig 21: SSD DRAM cache size sweep. Host budget kept at 4x SSD DRAM and
write log at 1/8 of SSD DRAM (paper's fixed ratios). Paper: SkyByte-Full
with a small SSD DRAM matches/beats Base-CSSD with much larger DRAM."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SimConfig

from benchmarks.common import TOTAL_REQ, collect_cells, cached_sim, print_csv

DRAM_MB = (128, 256, 512, 1024)  # at scale=1
WLS = ("bc", "srad", "tpcc", "dlrm")


def run(total_req: int = TOTAL_REQ, force: bool = False):
    rows = []
    for wl in WLS:
        for mb in DRAM_MB:
            cfg = dataclasses.replace(
                SimConfig(),
                ssd_dram_bytes=mb << 20,
                write_log_bytes=(mb // 8) << 20,
                host_dram_bytes=(mb * 4) << 20,
            )
            for v in ("base-cssd", "skybyte-full"):
                r = cached_sim(wl, v, cfg=cfg, total_req=total_req, force=force)
                rows.append({
                    "workload": wl, "ssd_dram_MB": mb, "variant": v,
                    "exec_ms": round(r["exec_ns"] / 1e6, 3),
                    "amat_ns": round(r["amat_ns"], 1),
                })
    return rows


def cells(total_req: int = TOTAL_REQ):
    """Cell specs this section will request (see common.collect_cells)."""
    return collect_cells(run, total_req)


def main(total_req: int = TOTAL_REQ, force: bool = False):
    rows = run(total_req, force)
    print_csv("fig21_dramsize (Full at small DRAM ~ Base at large DRAM)",
              rows, ["workload", "ssd_dram_MB", "variant", "exec_ms", "amat_ns"])
    return rows


if __name__ == "__main__":
    main()
