"""Fallback shim for the optional `hypothesis` dev dependency.

When hypothesis is installed, this module re-exports the real
given/settings/strategies so the property tests run at full strength.
When it is not (the CI image only guarantees the runtime deps), a minimal
deterministic sampler stands in: each @given test runs `max_examples`
randomly-drawn (but seed-fixed) cases instead of being skipped, so the
invariants still get exercised on every run.

Install the real thing with:  pip install hypothesis
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # tiny deterministic fallback
    import functools
    import inspect
    import random as _random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        __slots__ = ("sample",)

        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class _Strategies:
        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, allow_nan=True, allow_infinity=True,
                   **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=16, **_kw):
            return _Strategy(
                lambda rng: [elements.sample(rng)
                             for _ in range(rng.randint(min_size, max_size))])

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", _DEFAULT_EXAMPLES)
                rng = _random.Random(0)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in arg_strategies]
                    kdrawn = {k: s.sample(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)

            # hide strategy-filled parameters from pytest's fixture
            # resolution (hypothesis does the same)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[len(arg_strategies):]
            params = [p for p in params if p.name not in kw_strategies]
            runner.__signature__ = sig.replace(parameters=params)
            return runner

        return deco
