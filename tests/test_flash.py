"""Block-granular flash backend (core/flash.py): engine-parity corners,
FTL state invariants, victim-policy behaviour, and the decorrelated
legacy GC channel/die pick.

The backend's exactness contract is structural — every flash program runs
through the shared ``on_flash_write`` at the same sequence points in both
engines — but these corners drive it through its stress regimes (GC storm
at starvation-level over-provisioning, frequent compaction drains,
divergent victim policies) and assert bit-equality of the full Stats
dict, including the new waf / gc_migrated_pages / lat_p99_ns fields."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import SimConfig, VARIANTS
from repro.core.device_state import DIES_PER_CHANNEL, DeviceState
from repro.core.flash import BlockFtl, check_invariants
from repro.core.simulator import Machine, simulate
from repro.core.ssd import Channels
from repro.core.traces import gen_thread_trace, WORKLOADS


def _run(engine, workload, variant, n=6_000, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_same(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, (float, np.floating)) or isinstance(y, (float, np.floating)):
            assert float(x) == pytest.approx(float(y), rel=1e-12, abs=1e-9), \
                (k, x, y)
        else:
            assert x == y, (k, x, y)


# ---------------------------------------------------------------------------
# engine-parity corners
# ---------------------------------------------------------------------------

# starved spare pool + tiny log (32-entry buffers) + tiny host tier so
# the promotion variants churn demotion write-backs instead of parking
# the write set in host DRAM
STORM = dict(op_ratio=0.015, write_log_bytes=1 << 19,
             host_dram_bytes=64 << 20)


@pytest.mark.parametrize("variant", VARIANTS)
def test_parity_gc_storm_all_variants(variant):
    """Starvation-level over-provisioning (1.5%) plus a tiny write log:
    GC runs near-continuously on every program-generating variant, and
    both engines must stay bit-identical through the storm. (64k
    requests: the page cache must fill before evictions program flash.)"""
    a = _run("reference", "radix", variant, n=64_000, **STORM)
    b = _run("batched", "radix", variant, n=64_000, **STORM)
    if variant not in ("dram-only",):
        assert a["flash_writes"] > 0, "corner must program flash"
        assert a["gc_events"] > 0, "corner must trigger GC"
        assert a["waf"] > 1.0, "GC under pressure must migrate live pages"
    _assert_same(a, b)


@pytest.mark.parametrize("policy", ["greedy", "cost-benefit"])
def test_parity_victim_policies(policy):
    """Each victim policy is parity-clean between the engines."""
    over = dict(op_ratio=0.02, gc_policy=policy)
    a = _run("reference", "dlrm", "base-cssd", n=12_000, **over)
    b = _run("batched", "dlrm", "base-cssd", n=12_000, **over)
    assert a["gc_events"] > 0
    _assert_same(a, b)


def test_victim_policies_diverge():
    """Greedy and cost-benefit must actually pick different victims under
    sustained GC (otherwise the knob is dead weight)."""
    g = _run("batched", "dlrm", "base-cssd", n=12_000,
             op_ratio=0.02, gc_policy="greedy")
    cb = _run("batched", "dlrm", "base-cssd", n=12_000,
              op_ratio=0.02, gc_policy="cost-benefit")
    assert g["gc_events"] > 0 and cb["gc_events"] > 0
    assert (g["gc_migrated_pages"] != cb["gc_migrated_pages"]
            or g["exec_ns"] != cb["exec_ns"])


def test_waf_monotonic_in_log_size():
    """A larger write log coalesces more lines per flushed page, so total
    flash programs AND device write amplification never increase with
    log capacity — the measurable coupling between SkyByte's log and the
    flash backend that the legacy free-page counter could not express."""
    results = []
    for mb in (8, 32, 128):
        r = _run("batched", "srad", "skybyte-w", n=60_000,
                 write_log_bytes=mb << 20, op_ratio=0.02)
        results.append(r)
    assert results[0]["flash_writes"] > 0, "smallest log must reach flash"
    for small, big in zip(results, results[1:]):
        assert big["flash_writes"] <= small["flash_writes"]
        total_small = small["flash_writes"] + small["gc_migrated_pages"]
        total_big = big["flash_writes"] + big["gc_migrated_pages"]
        assert total_big <= total_small
        assert big["waf"] <= small["waf"] + 1e-9


def test_legacy_backend_parity_and_knob():
    """ftl_backend="legacy" restores the free-page counter (no block
    state), stays engine-parity-clean, and rejects unknown values."""
    over = dict(ftl_backend="legacy", flash_bytes=2 << 30,
                ssd_dram_bytes=32 << 20, cache_ways=1)
    a = _run("reference", "radix", "base-cssd", n=16_000, **over)
    b = _run("batched", "radix", "base-cssd", n=16_000, **over)
    assert a["gc_events"] > 0
    assert "wear_max_erases" not in a  # block-FTL-only accounting
    _assert_same(a, b)
    with pytest.raises(ValueError):
        _run("batched", "radix", "base-cssd", n=1_000, ftl_backend="nvme")
    with pytest.raises(ValueError):
        _run("batched", "radix", "base-cssd", n=1_000, gc_policy="oracle")


# ---------------------------------------------------------------------------
# latency percentiles
# ---------------------------------------------------------------------------

def test_percentiles_ordered_and_exact_constants():
    r = simulate("srad", "base-cssd", total_req=20_000)
    assert 0 < r["lat_p50_ns"] <= r["lat_p95_ns"] <= r["lat_p99_ns"]
    d = simulate("ycsb", "dram-only", total_req=20_000)
    # every dram-only request has the constant host latency: percentiles
    # land on the exact constant class, not a histogram bin edge
    assert d["lat_p50_ns"] == d["lat_p95_ns"] == d["lat_p99_ns"] == 70.0


def test_gc_pressure_raises_tail():
    """GC busy windows must surface in the p99 read tail: the same cell
    with starved over-provisioning has a tail at least as bad as with
    ample spare space."""
    tight = _run("batched", "dlrm", "base-cssd", n=20_000, op_ratio=0.015)
    roomy = _run("batched", "dlrm", "base-cssd", n=20_000, op_ratio=0.5)
    assert tight["gc_events"] > roomy["gc_events"]
    assert tight["lat_p99_ns"] >= roomy["lat_p99_ns"]


# ---------------------------------------------------------------------------
# FTL state invariants (property sweep)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    wl=st.sampled_from(sorted(WORKLOADS)),
    op=st.sampled_from([0.02, 0.06, 0.25]),
    policy=st.sampled_from(["greedy", "cost-benefit"]),
    seed=st.integers(0, 3),
)
def test_ftl_invariants_under_serve(wl, op, policy, seed):
    """Drive the full policy stack (serve -> evictions -> programs -> GC)
    and assert the valid-count / bitmap / l2p-p2l / free-pool invariants
    afterwards, plus conservation of the mapped logical space."""
    cfg = dataclasses.replace(SimConfig().variant("base-cssd"),
                              op_ratio=op, gc_policy=policy)
    tr = gen_thread_trace(WORKLOADS[wl], 4_000, seed, scale=128)
    page_space = int(tr["n_pages"])
    m = Machine(cfg, seed=seed, page_space=page_space)
    wslots = []
    now = 0.0
    for p, l, w in zip(tr["page"].tolist(), tr["line"].tolist(),
                       tr["write"].tolist()):
        now += 50.0
        lat, blocked, _ = m.serve(int(p), int(l), bool(w), now, wslots)
        now += lat if blocked is None else 0.0
    fs = m.state.flash
    check_invariants(fs)
    # precondition maps every logical page; programs only remap, so the
    # whole logical space stays mapped forever
    assert (fs.l2p >= 0).all()
    assert int(fs.blk_valid.sum()) == page_space
    if m.state.gc_events:
        assert m.state.gc_migrated_pages >= 0
        assert int(fs.blk_erase.sum()) == m.state.gc_events


def test_seal_time_gc_keeps_inflight_page_mapped():
    """Regression: a program that fills the host frontier while every
    earlier slot is already invalidated (rewrite-heavy locality) must not
    let seal-time GC erase the block before the in-flight page's mapping
    lands — the write would silently vanish when the slot is reused.
    Geometry: 4-page blocks, zero spare beyond the floor, page 0
    rewritten until its block seals fully-invalid-but-for-the-last-slot."""
    cfg = dataclasses.replace(SimConfig(), pages_per_block=4, op_ratio=0.0)
    ds = DeviceState(cfg, 8)
    ftl = BlockFtl(cfg, ds, Channels(cfg, ds))
    now = 0.0
    for step in range(64):  # hammer rewrites + fresh pages through seals
        page = 0 if step % 2 == 0 else (step // 2) % 8
        now += 100.0
        ftl.on_flash_write(now, page)
        check_invariants(ds.flash)
        pp = int(ds.flash.l2p[page])
        assert pp >= 0 and bool(ds.flash.pvalid[pp])
        assert int(ds.flash.p2l[pp]) == page, \
            "in-flight page lost its mapping across seal-time GC"


def test_blockftl_initial_state():
    cfg = SimConfig().variant("base-cssd")
    ds = DeviceState(cfg, 1_000)
    fs = ds.flash
    check_invariants(fs)
    assert int(fs.blk_valid.sum()) == 1_000
    assert fs.n_blocks * fs.ppb >= int(1_000 * (1 + cfg.op_ratio))
    BlockFtl(cfg, ds, Channels(cfg, ds))  # constructs cleanly


# ---------------------------------------------------------------------------
# legacy Channels.gc decorrelation (satellite fix)
# ---------------------------------------------------------------------------

def test_legacy_gc_channel_die_decorrelated():
    """The historical pick advanced channel and die in lockstep
    (gc_events % n_channels, gc_events % DIES_PER_CHANNEL), so with 16
    channels dividing 64 dies only the 64 diagonal pairs out of 1024 ever
    absorbed GC work. The decorrelated stride must cover every (channel,
    die) pair exactly once per 1024 events."""
    cfg = dataclasses.replace(SimConfig(), ftl_backend="legacy")
    ds = DeviceState(cfg, 64)
    ch = Channels(cfg, ds)
    pairs = set()
    n_pairs = cfg.n_channels * DIES_PER_CHANNEL
    for _ in range(n_pairs):
        before = [list(d) for d in ds.chan_die]
        ch.gc(0.0)
        for ci in range(cfg.n_channels):
            for di in range(DIES_PER_CHANNEL):
                if ds.chan_die[ci][di] != before[ci][di]:
                    pairs.add((ci, di))
    assert len(pairs) == n_pairs, \
        f"GC only ever touched {len(pairs)}/{n_pairs} (channel, die) pairs"
    assert ds.gc_events == n_pairs
    assert ds.gc_migrated_pages == 8 * n_pairs
