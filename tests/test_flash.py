"""Block-granular flash backend (core/flash.py): engine-parity corners,
FTL state invariants, victim-policy behaviour, and the decorrelated
legacy GC channel/die pick.

The backend's exactness contract is structural — every flash program runs
through the shared ``on_flash_write`` at the same sequence points in both
engines — but these corners drive it through its stress regimes (GC storm
at starvation-level over-provisioning, frequent compaction drains,
divergent victim policies) and assert bit-equality of the full Stats
dict, including the new waf / gc_migrated_pages / lat_p99_ns fields."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import SimConfig, VARIANTS
from repro.core.device_state import DIES_PER_CHANNEL, DeviceState
from repro.core.engine import BatchedMachine, batched_quantum
from repro.core.flash import BlockFtl, blk_loc, check_invariants
from repro.core.simulator import Machine, Thread, _reference_quantum, simulate
from repro.core.ssd import Channels
from repro.core.traces import gen_thread_trace, WORKLOADS


def _run(engine, workload, variant, n=6_000, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_same(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, (float, np.floating)) or isinstance(y, (float, np.floating)):
            assert float(x) == pytest.approx(float(y), rel=1e-12, abs=1e-9), \
                (k, x, y)
        else:
            assert x == y, (k, x, y)


# ---------------------------------------------------------------------------
# engine-parity corners
# ---------------------------------------------------------------------------

# starved spare pool + tiny log (32-entry buffers) + tiny host tier so
# the promotion variants churn demotion write-backs instead of parking
# the write set in host DRAM
STORM = dict(op_ratio=0.015, write_log_bytes=1 << 19,
             host_dram_bytes=64 << 20)


@pytest.mark.parametrize("variant", VARIANTS)
def test_parity_gc_storm_all_variants(variant):
    """Starvation-level over-provisioning (1.5%) plus a tiny write log:
    GC runs near-continuously on every program-generating variant, and
    both engines must stay bit-identical through the storm. (64k
    requests: the page cache must fill before evictions program flash.)"""
    a = _run("reference", "radix", variant, n=64_000, **STORM)
    b = _run("batched", "radix", variant, n=64_000, **STORM)
    if variant not in ("dram-only",):
        assert a["flash_writes"] > 0, "corner must program flash"
        assert a["gc_events"] > 0, "corner must trigger GC"
        assert a["waf"] > 1.0, "GC under pressure must migrate live pages"
    _assert_same(a, b)


@pytest.mark.parametrize("policy", ["greedy", "cost-benefit"])
def test_parity_victim_policies(policy):
    """Each victim policy is parity-clean between the engines."""
    over = dict(op_ratio=0.02, gc_policy=policy)
    a = _run("reference", "dlrm", "base-cssd", n=12_000, **over)
    b = _run("batched", "dlrm", "base-cssd", n=12_000, **over)
    assert a["gc_events"] > 0
    _assert_same(a, b)


def test_victim_policies_diverge():
    """Greedy and cost-benefit must actually pick different victims under
    sustained GC (otherwise the knob is dead weight)."""
    g = _run("batched", "dlrm", "base-cssd", n=12_000,
             op_ratio=0.02, gc_policy="greedy")
    cb = _run("batched", "dlrm", "base-cssd", n=12_000,
              op_ratio=0.02, gc_policy="cost-benefit")
    assert g["gc_events"] > 0 and cb["gc_events"] > 0
    assert (g["gc_migrated_pages"] != cb["gc_migrated_pages"]
            or g["exec_ns"] != cb["exec_ns"])


def test_waf_monotonic_in_log_size():
    """A larger write log coalesces more lines per flushed page, so total
    flash programs AND device write amplification never increase with
    log capacity — the measurable coupling between SkyByte's log and the
    flash backend that the legacy free-page counter could not express."""
    results = []
    for mb in (8, 32, 128):
        r = _run("batched", "srad", "skybyte-w", n=60_000,
                 write_log_bytes=mb << 20, op_ratio=0.02)
        results.append(r)
    assert results[0]["flash_writes"] > 0, "smallest log must reach flash"
    for small, big in zip(results, results[1:]):
        assert big["flash_writes"] <= small["flash_writes"]
        total_small = small["flash_writes"] + small["gc_migrated_pages"]
        total_big = big["flash_writes"] + big["gc_migrated_pages"]
        assert total_big <= total_small
        assert big["waf"] <= small["waf"] + 1e-9


def test_legacy_backend_parity_and_knob():
    """ftl_backend="legacy" restores the free-page counter (no block
    state), stays engine-parity-clean, and rejects unknown values."""
    over = dict(ftl_backend="legacy", flash_bytes=2 << 30,
                ssd_dram_bytes=32 << 20, cache_ways=1)
    a = _run("reference", "radix", "base-cssd", n=16_000, **over)
    b = _run("batched", "radix", "base-cssd", n=16_000, **over)
    assert a["gc_events"] > 0
    assert "wear_max_erases" not in a  # block-FTL-only accounting
    _assert_same(a, b)
    with pytest.raises(ValueError):
        _run("batched", "radix", "base-cssd", n=1_000, ftl_backend="nvme")
    with pytest.raises(ValueError):
        _run("batched", "radix", "base-cssd", n=1_000, gc_policy="oracle")


# ---------------------------------------------------------------------------
# latency percentiles
# ---------------------------------------------------------------------------

def test_percentiles_ordered_and_exact_constants():
    r = simulate("srad", "base-cssd", total_req=20_000)
    assert 0 < r["lat_p50_ns"] <= r["lat_p95_ns"] <= r["lat_p99_ns"]
    d = simulate("ycsb", "dram-only", total_req=20_000)
    # every dram-only request has the constant host latency: percentiles
    # land on the exact constant class, not a histogram bin edge
    assert d["lat_p50_ns"] == d["lat_p95_ns"] == d["lat_p99_ns"] == 70.0


def test_gc_pressure_raises_tail():
    """GC busy windows must surface in the p99 read tail: the same cell
    with starved over-provisioning has a tail at least as bad as with
    ample spare space."""
    tight = _run("batched", "dlrm", "base-cssd", n=20_000, op_ratio=0.015)
    roomy = _run("batched", "dlrm", "base-cssd", n=20_000, op_ratio=0.5)
    assert tight["gc_events"] > roomy["gc_events"]
    assert tight["lat_p99_ns"] >= roomy["lat_p99_ns"]


# ---------------------------------------------------------------------------
# FTL state invariants (property sweep)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    wl=st.sampled_from(sorted(WORKLOADS)),
    op=st.sampled_from([0.02, 0.06, 0.25]),
    policy=st.sampled_from(["greedy", "cost-benefit"]),
    seed=st.integers(0, 3),
)
def test_ftl_invariants_under_serve(wl, op, policy, seed):
    """Drive the full policy stack (serve -> evictions -> programs -> GC)
    and assert the valid-count / bitmap / l2p-p2l / free-pool invariants
    afterwards, plus conservation of the mapped logical space."""
    cfg = dataclasses.replace(SimConfig().variant("base-cssd"),
                              op_ratio=op, gc_policy=policy)
    tr = gen_thread_trace(WORKLOADS[wl], 4_000, seed, scale=128)
    page_space = int(tr["n_pages"])
    m = Machine(cfg, seed=seed, page_space=page_space)
    wslots = []
    now = 0.0
    for p, l, w in zip(tr["page"].tolist(), tr["line"].tolist(),
                       tr["write"].tolist()):
        now += 50.0
        lat, blocked, _ = m.serve(int(p), int(l), bool(w), now, wslots)
        now += lat if blocked is None else 0.0
    fs = m.state.flash
    check_invariants(fs)
    # precondition maps every logical page; programs only remap, so the
    # whole logical space stays mapped forever
    assert (fs.l2p >= 0).all()
    assert int(fs.blk_valid.sum()) == page_space
    if m.state.gc_events:
        assert m.state.gc_migrated_pages >= 0
        assert int(fs.blk_erase.sum()) == m.state.gc_events


def test_seal_time_gc_keeps_inflight_page_mapped():
    """Regression: a program that fills the host frontier while every
    earlier slot is already invalidated (rewrite-heavy locality) must not
    let seal-time GC erase the block before the in-flight page's mapping
    lands — the write would silently vanish when the slot is reused.
    Geometry: 4-page blocks, zero spare beyond the floor, page 0
    rewritten until its block seals fully-invalid-but-for-the-last-slot."""
    cfg = dataclasses.replace(SimConfig(), pages_per_block=4, op_ratio=0.0)
    ds = DeviceState(cfg, 8)
    ftl = BlockFtl(cfg, ds, Channels(cfg, ds))
    now = 0.0
    for step in range(64):  # hammer rewrites + fresh pages through seals
        page = 0 if step % 2 == 0 else (step // 2) % 8
        now += 100.0
        ftl.on_flash_write(now, page)
        check_invariants(ds.flash)
        pp = int(ds.flash.l2p[page])
        assert pp >= 0 and bool(ds.flash.pvalid[pp])
        assert int(ds.flash.p2l[pp]) == page, \
            "in-flight page lost its mapping across seal-time GC"


def test_blockftl_initial_state():
    cfg = SimConfig().variant("base-cssd")
    ds = DeviceState(cfg, 1_000)
    fs = ds.flash
    check_invariants(fs)
    assert int(fs.blk_valid.sum()) == 1_000
    assert fs.n_blocks * fs.ppb >= int(1_000 * (1 + cfg.op_ratio))
    BlockFtl(cfg, ds, Channels(cfg, ds))  # constructs cleanly


# ---------------------------------------------------------------------------
# legacy Channels.gc decorrelation (satellite fix)
# ---------------------------------------------------------------------------

def test_legacy_gc_channel_die_decorrelated():
    """The historical pick advanced channel and die in lockstep
    (gc_events % n_channels, gc_events % DIES_PER_CHANNEL), so with 16
    channels dividing 64 dies only the 64 diagonal pairs out of 1024 ever
    absorbed GC work. The decorrelated stride must cover every (channel,
    die) pair exactly once per 1024 events."""
    cfg = dataclasses.replace(SimConfig(), ftl_backend="legacy")
    ds = DeviceState(cfg, 64)
    ch = Channels(cfg, ds)
    pairs = set()
    n_pairs = cfg.n_channels * DIES_PER_CHANNEL
    for _ in range(n_pairs):
        before = [list(d) for d in ds.chan_die]
        ch.gc(0.0)
        for ci in range(cfg.n_channels):
            for di in range(DIES_PER_CHANNEL):
                if ds.chan_die[ci][di] != before[ci][di]:
                    pairs.add((ci, di))
    assert len(pairs) == n_pairs, \
        f"GC only ever touched {len(pairs)}/{n_pairs} (channel, die) pairs"
    assert ds.gc_events == n_pairs
    assert ds.gc_migrated_pages == 8 * n_pairs


# ---------------------------------------------------------------------------
# physical-address-routed service path (l2p-driven channel/die queueing)
# ---------------------------------------------------------------------------

def _serve_trace(m, tr, n):
    """Drive n events of a thread trace through the serve() oracle."""
    wslots = []
    now = 0.0
    for p, l, w in zip(tr["page"][:n].tolist(), tr["line"][:n].tolist(),
                       tr["write"][:n].tolist()):
        now += 50.0
        lat, blocked, _ = m.serve(int(p), int(l), bool(w), now, wslots)
        now += lat if blocked is None else 0.0
    return m


def _drive(machine_cls, runner, cfg, tr, seed=0):
    """Run one thread's full trace through a replay engine directly
    (single core), exposing the Machine so tests can inspect the FTL
    mapping — simulate() only returns the stats dict."""
    th = Thread(0, tr)
    m = machine_cls(cfg, seed, int(tr["n_pages"]))
    wslots = []
    t = 0.0
    while th.i < th.n:
        if t < th.ready:
            t = th.ready
        t = runner(m, cfg, th, t, wslots)
    return m


def test_routing_logical_loc_is_the_single_legacy_hash():
    """Satellite: the four historical copies of the logical channel hash
    collapsed into Channels.logical_loc — it must still compute the exact
    PR 4 stripe, and the legacy resolver must BE it."""
    cfg = dataclasses.replace(SimConfig(), ftl_backend="legacy")
    m = Machine(cfg, 0, 4096)
    for page in (0, 1, 17, 255, 4095):
        assert m.channels.logical_loc(page) == (
            (page * 1103515245 + 12345) % cfg.n_channels,
            (page // cfg.n_channels) % DIES_PER_CHANNEL)
    assert m.loc_of == m.channels.logical_loc


def test_routing_block_follows_l2p_and_diverges_from_legacy():
    """Block routing must resolve (channel, die) from the FTL's physical
    placement at all times — identity at precondition, and diverging from
    the logical stripe once rewrites move pages through the frontiers."""
    cfg = dataclasses.replace(SimConfig(), op_ratio=0.02)
    tr = gen_thread_trace(WORKLOADS["srad"], 20_000, 0, scale=128)
    m = Machine(cfg, 0, int(tr["n_pages"]))
    fs = m.state.flash
    # preconditioned: page p sits in block p // ppb
    for p in (0, 3, 100, int(tr["n_pages"]) - 1):
        assert m.loc_of(p) == blk_loc(p // fs.ppb, cfg.n_channels)
    _serve_trace(m, tr, 20_000)
    assert m.state.gc_events > 0, "corner must exercise GC relocation"
    n_pages = int(tr["n_pages"])
    diverged = moved = 0
    for p in range(0, n_pages, 7):
        blk = int(fs.l2p[p]) // fs.ppb
        assert m.loc_of(p) == blk_loc(blk, cfg.n_channels)
        if blk != p // fs.ppb:
            moved += 1
        if m.loc_of(p) != m.channels.logical_loc(p):
            diverged += 1
    assert moved > 0, "rewrites/GC must physically move pages"
    assert diverged > 0, \
        "physical routing must diverge from the legacy logical stripe"


@pytest.mark.parametrize("wear,hc", [(False, False), (True, False),
                                     (False, True), (True, True)])
def test_routing_parity_storm_wear_hotcold(wear, hc):
    """Engine parity through GC storms for every placement-policy combo:
    wear-aware free-block picks and hot/cold frontier splits both run in
    shared FTL code, so batched and reference must stay bit-identical."""
    over = dict(STORM, wear_leveling=wear, hotcold=hc)
    a = _run("reference", "radix", "skybyte-full", n=32_000, **over)
    b = _run("batched", "radix", "skybyte-full", n=32_000, **over)
    assert a["gc_events"] > 0
    _assert_same(a, b)


@settings(max_examples=6, deadline=None)
@given(
    policy=st.sampled_from(["greedy", "cost-benefit"]),
    wear=st.sampled_from([False, True]),
    hc=st.sampled_from([False, True]),
    seed=st.integers(0, 3),
)
def test_routing_l2p_agreement_property(policy, wear, hc, seed):
    """Property sweep (satellite): after GC churn the l2p/p2l mapping —
    and therefore the die every page is served from — must agree between
    the engines and with check_invariants, for both GC policies and
    wear-leveling/hotcold on and off."""
    cfg = dataclasses.replace(
        SimConfig().variant("skybyte-full"), op_ratio=0.015,
        gc_policy=policy, wear_leveling=wear, hotcold=hc,
        write_log_bytes=1 << 19, host_dram_bytes=64 << 20)
    tr = gen_thread_trace(WORKLOADS["radix"], 12_000, seed, scale=128)
    ma = _drive(Machine, _reference_quantum, cfg, tr, seed)
    mb = _drive(BatchedMachine, batched_quantum, cfg, tr, seed)
    fa, fb = ma.state.flash, mb.state.flash
    check_invariants(fa)
    check_invariants(fb)
    assert ma.state.gc_events == mb.state.gc_events
    assert (fa.l2p == fb.l2p).all(), "engines disagree on page placement"
    assert (fa.p2l == fb.p2l).all()
    assert (fa.blk_erase == fb.blk_erase).all(), "wear histories diverged"
    # die derived from p2l agrees between engines and with the resolver
    for pp in np.flatnonzero(fa.pvalid)[::17].tolist():
        lp = int(fa.p2l[pp])
        loc = blk_loc(pp // fa.ppb, cfg.n_channels)
        assert ma.loc_of(lp) == loc
        assert mb.loc_of(lp) == loc


def test_routing_wear_leveling_flattens_spread():
    """LIFO free-pool pops recycle the same freshly-erased blocks, so a
    GC-heavy cell concentrates erases (wear_max >> mean); the lowest-
    erase-count pick must flatten that spread."""
    off = _run("batched", "dlrm", "base-cssd", n=100_000)
    on = _run("batched", "dlrm", "base-cssd", n=100_000, wear_leveling=True)
    assert off["gc_events"] > 100 and on["gc_events"] > 100
    assert on["wear_max_erases"] < off["wear_max_erases"]
    spread_off = off["wear_max_erases"] / max(off["wear_mean_erases"], 1e-9)
    spread_on = on["wear_max_erases"] / max(on["wear_mean_erases"], 1e-9)
    assert spread_on < spread_off, (spread_on, spread_off)


def test_routing_hotcold_splits_host_frontier():
    """Rewrite heat routes programs: a page whose previous copy is still
    in an OPEN block re-programs through the hot frontier; first-touch
    (cold) programs stay on the cold host frontier."""
    cfg = dataclasses.replace(SimConfig(), hotcold=True, pages_per_block=8,
                              op_ratio=1.0)
    # 40 precondition blocks -> heat window 10 seal ticks: page 5's
    # precondition block (id 0, seal age 39) is safely outside it
    ds = DeviceState(cfg, 320)
    fs = ds.flash
    assert fs.hot_blk >= 0 and fs.blk_state[fs.hot_blk] == 1
    ftl = BlockFtl(cfg, ds, Channels(cfg, ds))
    cold_b = fs.host_blk
    ftl.on_flash_write(0.0, 5)  # old copy in an old sealed precondition block
    assert int(fs.l2p[5]) // fs.ppb == cold_b, "first touch must go cold"
    hot_b = fs.hot_blk
    ftl.on_flash_write(1.0, 5)  # old copy now sits in the open cold frontier
    assert int(fs.l2p[5]) // fs.ppb == hot_b, "rewrite must go hot"
    ftl.on_flash_write(2.0, 5)  # and stays hot while its copy is hot-open
    assert int(fs.l2p[5]) // fs.ppb == hot_b
    check_invariants(fs)
    # knob off: no hot frontier exists
    ds2 = DeviceState(dataclasses.replace(cfg, hotcold=False), 320)
    assert ds2.flash.hot_blk == -1


def test_routing_gc_pause_attribution():
    """fig14's GC attribution: synchronous read misses whose die wait
    overlaps a GC-carved window must be counted on write-heavy cells,
    with sane bounds, and stay zero where no GC can run. (Device-internal
    reads — compaction fills, write-allocate background fetches — book
    nothing, so the counts are sparse but strictly host-observed.)"""
    r = _run("batched", "dlrm", "base-cssd", n=100_000, op_ratio=0.015)
    assert r["gc_events"] > 0
    assert r["gc_stall_events"] > 0, "GC storms must stall some reads"
    assert 0 < r["gc_pause_max_ns"] <= r["gc_pause_ns_total"]
    d = _run("batched", "ycsb", "dram-only", n=4_000)
    assert d["gc_stall_events"] == 0 and d["gc_pause_ns_total"] == 0
