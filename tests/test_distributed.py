"""Multi-device (8 fake CPU devices) pjit integration: the production code
path — sharded params, gradient accumulation, batch sharding — executes
(not just lowers) on a (2, 4) data x model mesh. Runs in a subprocess so
the device-count flag doesn't leak into other tests."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import OptimConfig, get_reduced
    from repro.distributed.sharding import batch_spec, param_specs
    from repro.launch.steps import build_train_step, make_train_state
    from repro.models.api import ModelSpec

    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = ModelSpec(get_reduced("qwen3-1.7b"))
    schema = spec.schema()
    with mesh:
        psp = param_specs(schema, mesh)
        p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), psp)
        state = make_train_state(spec, jax.random.PRNGKey(0))
        state = {
            "params": jax.device_put(state["params"], p_sh),
            "opt": type(state["opt"])(
                jax.device_put(state["opt"].step, NamedSharding(mesh, P())),
                jax.device_put(state["opt"].mu, p_sh),
                jax.device_put(state["opt"].nu, p_sh),
                jax.device_put(state["opt"].master, p_sh),
            ),
        }
        step = jax.jit(build_train_step(spec, OptimConfig(lr=1e-3), accum_steps=2),
                       donate_argnums=0)
        batch = {"tokens": jax.device_put(
            jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 100, jnp.int32),
            NamedSharding(mesh, batch_spec(mesh)))}
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(l == l for l in losses), losses  # finite
        assert losses[2] < losses[0], losses  # memorizing one batch
        print("DISTRIBUTED-OK", losses)
    """
)


def test_multidevice_train_step():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=420,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "DISTRIBUTED-OK" in r.stdout
