"""Latency provenance (core/obs.py): the conservation contract, engine
parity of the whole obs artifact, zero-obs invisibility, interval-ring
totals, and the Perfetto trace export.

The load-bearing property is CONSERVATION: for every retired
host-visible read miss and write stall, the attributed components sum
bit-exactly to the latency the engine recorded (closure nudges the
queue slot; an unclosable event collapses to one slot and is counted in
closure_fallbacks — ``violations`` must be structurally zero). The
second structural property is that the obs artifact is identical across
engines: obs is a conflict class, both engines route every flash read
through the one staging site and retire in the same global order, so
the whole JSON block must compare equal — not approximately."""
import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs.base import FaultConfig, ObsConfig, SimConfig, VARIANTS
from repro.core import engine as engine_mod
from repro.core.obs import _RCHAIN, to_perfetto
from repro.core.simulator import (Machine, percentiles_from_items, simulate)

OBS = ObsConfig(enabled=True)

# GC near-continuously live (same knobs as the QoS suite's storm cell)
STORM = dict(op_ratio=0.015, write_log_bytes=1 << 19,
             host_dram_bytes=64 << 20)

# the four regimes the attribution chain has distinct slots for
SCENARIOS = {
    "baseline": dict(),
    "gc-storm": dict(STORM),
    "qos": dict(STORM, gc_suspend=True, read_priority=True),
    "fault": dict(STORM, fault=FaultConfig(
        read_error_rate=3e-3, outage_rate=1e-3,
        power_loss_at=(500,), die_fail_at=(900,))),
}

N_REQ = 40_000


def _run(engine, workload, variant, n=N_REQ, seed=0, obs=OBS, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, obs=obs,
                              **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------

def test_odd_or_tiny_window_ring_rejected():
    for bad in (0, 1, 3, 255):
        with pytest.raises(ValueError, match="max_windows"):
            dataclasses.replace(SimConfig(), obs=ObsConfig(
                enabled=True, max_windows=bad))


def test_nonpositive_window_rejected():
    with pytest.raises(ValueError, match="window_ns"):
        dataclasses.replace(SimConfig(), obs=ObsConfig(
            enabled=True, window_ns=0.0))


def test_disabled_obs_knobs_not_validated():
    # enabled=False configs never construct an ObsModel; bad knobs in a
    # dormant block must not break unrelated cells
    dataclasses.replace(SimConfig(), obs=ObsConfig(max_windows=3))


# ---------------------------------------------------------------------------
# conservation + engine parity: the full scenario sweep
# ---------------------------------------------------------------------------

def _check_conservation(r):
    ob = r["obs"]
    c = ob["conservation"]
    assert c["violations"] == 0
    assert c["pass"], c
    assert c["gc_pause_exact"]
    assert c["gc_pause_site_ns"] == c["gc_pause_device_ns"]
    assert c["checked"] == ob["n_miss"] + ob["n_stall"]
    # commit counts mirror the Stats classes one-for-one
    assert ob["n_miss"] == r["miss_flash"]
    assert ob["n_stall"] == r["ssd_w_var"]
    return ob


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_conservation_and_parity(variant, scenario):
    over = SCENARIOS[scenario]
    if variant == "dram-only" and scenario != "baseline":
        pytest.skip("no flash traffic to attribute")
    blocks = []
    for engine in ("reference", "batched"):
        r = _run(engine, "ycsb", variant, **over)
        ob = _check_conservation(r)
        blocks.append(json.dumps(ob, sort_keys=True))
    # bit-exact artifact parity: same staging site, same retire order
    assert blocks[0] == blocks[1]


def test_fault_scenario_attributes_fault_slots():
    r = _run("reference", "ycsb", "base-cssd", cache_ways=1,
             ssd_dram_bytes=32 << 20, host_dram_bytes=64 << 20,
             fault=SCENARIOS["fault"]["fault"])
    ob = _check_conservation(r)
    comps = ob["components"]
    # the armed fault classes must actually land in their own slots
    assert comps["retry"]["total_ns"] > 0.0
    assert comps["outage"]["total_ns"] > 0.0
    assert any(e["kind"] == "recovery" for e in ob["events"]["list"])


def test_gc_storm_attributes_pause_exactly():
    r = _run("reference", "dlrm", "base-cssd", **STORM)
    ob = _check_conservation(r)
    assert r["gc_pause_ns_total"] > 0.0
    # base-cssd retires every staged read (no parking), so the staged
    # pause totals are exactly the device-side counter
    assert ob["components"]["gc_pause"]["total_ns"] == r["gc_pause_ns_total"]


def test_slowest_k_parts_sum_to_latency():
    r = _run("reference", "dlrm", "base-cssd", **STORM)
    slowest = r["obs"]["slowest"]
    assert slowest
    lats = [s["lat_ns"] for s in slowest]
    assert lats == sorted(lats, reverse=True)
    for s in slowest:
        assert tuple(s["parts"]) == _RCHAIN  # insertion order = chain order
        total = 0.0
        for name in _RCHAIN:  # same left-to-right order closure verified
            total += s["parts"][name]
        assert total == s["lat_ns"]


# ---------------------------------------------------------------------------
# zero-obs: nothing attached, fused engine stays eligible
# ---------------------------------------------------------------------------

def test_zero_obs_attaches_nothing():
    m = Machine(SimConfig().variant("base-cssd"), 0, 1 << 14)
    assert m.obs is None
    assert m.channels.obs is None
    assert m.state.obs is None


def test_zero_obs_keeps_fused_engine_eligible():
    _run("batched", "ycsb", "skybyte-w", obs=ObsConfig())
    assert engine_mod.FUSED_STATS["fused_events"] > 0
    r = _run("batched", "ycsb", "skybyte-w")
    # obs is a conflict class: the mega-loop must refuse and fall back
    assert engine_mod.FUSED_STATS["fused_events"] == 0
    assert "obs" in r


def test_zero_obs_result_has_no_obs_block():
    r = _run("reference", "ycsb", "base-cssd", obs=ObsConfig())
    assert "obs" not in r


# ---------------------------------------------------------------------------
# interval ring
# ---------------------------------------------------------------------------

def test_interval_totals_match_end_of_run():
    r = _run("reference", "dlrm", "base-cssd", **STORM)
    ob = r["obs"]
    ws = ob["intervals"]["windows"]
    comps = ob["components"]
    assert sum(w["reads"] for w in ws) == ob["n_miss"]
    assert sum(w["misses"] for w in ws) == ob["n_miss"]
    assert sum(w["stalls"] for w in ws) == ob["n_stall"]
    assert sum(w["gc_migrated"] for w in ws) == r["gc_migrated_pages"]
    staged_pause = (comps["gc_pause"]["total_ns"]
                    + comps["gc_suspend"]["total_ns"])
    assert sum(w["gc_pause_ns"] for w in ws) == pytest.approx(staged_pause)


def test_interval_ring_folds_and_preserves_totals():
    tight = ObsConfig(enabled=True, max_windows=4)
    a = _run("reference", "dlrm", "base-cssd", obs=tight, **STORM)
    b = _run("reference", "dlrm", "base-cssd", **STORM)
    ia, ib = a["obs"]["intervals"], b["obs"]["intervals"]
    assert ia["folds"] > 0
    assert ia["n_windows"] <= 4
    assert ia["window_ns"] == b["obs"]["meta"]["window_ns"] * 2 ** (
        ia["folds"] - ib["folds"])
    for key in ("reads", "misses", "stalls", "gc_migrated"):
        assert (sum(w[key] for w in ia["windows"])
                == sum(w[key] for w in ib["windows"]))


# ---------------------------------------------------------------------------
# perfetto export
# ---------------------------------------------------------------------------

def _valid_trace(trace):
    assert set(trace) >= {"traceEvents", "displayTimeUnit"}
    assert trace["displayTimeUnit"] == "ns"
    pids = set()
    for ev in trace["traceEvents"]:
        assert ev["ph"] in ("X", "M", "i", "s", "f")
        assert isinstance(ev["pid"], int)
        pids.add(ev["pid"])
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
            assert ev["ts"] >= 0.0
        if ev["ph"] in ("s", "f"):
            assert "id" in ev
    # every referenced pid must carry a process_name metadata record
    named = {ev["pid"] for ev in trace["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert pids <= named


def test_perfetto_export_is_valid_and_deterministic():
    r1 = _run("reference", "dlrm", "base-cssd", **STORM)
    r2 = _run("batched", "dlrm", "base-cssd", **STORM)
    t1 = to_perfetto(r1["obs"], title="t")
    t2 = to_perfetto(r2["obs"], title="t")
    _valid_trace(t1)
    assert json.dumps(t1, sort_keys=True) == json.dumps(t2, sort_keys=True)
    names = {e["name"] for e in t1["traceEvents"] if e["ph"] == "X"}
    assert "gc_window" in names  # the storm must be visible on the track


def test_trace_export_cli_writes_valid_json(tmp_path):
    out = tmp_path / "trace.json"
    root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, str(root / "scripts" / "trace_export.py"),
         "--workload", "ycsb", "--variant", "base-cssd",
         "--total-req", "30000", "-o", str(out)],
        capture_output=True, text=True, cwd=root,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr
    _valid_trace(json.loads(out.read_text()))


# ---------------------------------------------------------------------------
# shared percentile helper (satellite: one implementation, two callers)
# ---------------------------------------------------------------------------

def test_percentiles_from_items_walks_the_multiset():
    items = [(10.0, 50), (20.0, 49), (1000.0, 1)]
    p50, p95, p99 = percentiles_from_items(items, 100)
    assert (p50, p95, p99) == (10.0, 20.0, 20.0)
    assert percentiles_from_items(items, 100, (1.0,)) == [1000.0]
    assert percentiles_from_items([], 0) == [0.0, 0.0, 0.0]
