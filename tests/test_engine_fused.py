"""Fused cross-thread boundary engine: conflict-fallback parity.

core/engine.run_fused processes each runnable thread's events in staged
windows; any same-set cache collision, same-l2p retouch (a page rewritten
or GC-migrated and re-read inside one window), promotion, log fill, or GC
must resolve through the exact per-event kernel paths or the scalar span
fallback. These sweeps shrink the cache to one way, the flash array to
GC-churn size, and the host tier to a few dozen pages so collisions are
guaranteed WITHIN single windows, then assert parity with the reference
loop — bit-exact equality, not approximate: the fused kernel replays the
reference's sequential float-addition order, so every output value must
be identical down to the last bit."""
import dataclasses

import pytest

from repro.configs.base import SimConfig, VARIANTS
from repro.core import engine as _engine
from repro.core.simulator import simulate

from tests._hypothesis_compat import given, settings, st

# Collision-forcing overrides: one-way sets make every same-set pair of
# pages a conflict; a small flash array + tiny write log keep l2p entries
# churning (GC migrations + compaction flushes), so windows see same-set
# and same-l2p collisions back to back.
CONFLICT_OVER = dict(
    cache_ways=1, ssd_dram_bytes=32 << 20, flash_bytes=2 << 30,
    write_log_bytes=1 << 20, host_dram_bytes=64 << 20,
)


def _run(engine, workload, variant, n, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_bit_exact(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k] == b[k], (k, a[k], b[k])


@pytest.mark.parametrize("variant", VARIANTS)
def test_fused_conflict_window_parity(variant):
    """Same-set/same-l2p collisions inside one window, all 8 variants."""
    a = _run("reference", "tpcc", variant, n=12_000, **CONFLICT_OVER)
    b = _run("batched", "tpcc", variant, n=12_000, **CONFLICT_OVER)
    _assert_bit_exact(a, b)


def test_fused_conflict_window_actually_conflicts():
    """The collision config must really churn mappings mid-window (GC
    migrations rewrite l2p entries that later events re-read), otherwise
    the sweep above proves nothing."""
    out = _run("batched", "tpcc", "skybyte-w", n=12_000, **CONFLICT_OVER)
    assert out["gc_events"] > 0
    assert out["compactions"] > 0
    assert _engine.FUSED_STATS["fused_events"] > 0


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["bfs-dense", "srad", "tpcc", "radix"]),
       st.sampled_from(VARIANTS),
       st.integers(min_value=0, max_value=5),
       st.sampled_from(["greedy", "cost-benefit"]),
       st.booleans())
def test_fused_window_property_sweep(workload, variant, seed, gc_policy,
                                     wear):
    """Randomized cells under collision pressure stay bit-exact, across
    both GC victim policies (lazy-heap greedy and the cost-benefit scan)
    and wear-leveling free-pool picks."""
    over = dict(CONFLICT_OVER, gc_policy=gc_policy, wear_leveling=wear)
    a = _run("reference", workload, variant, n=6_000, seed=seed, **over)
    b = _run("batched", workload, variant, n=6_000, seed=seed, **over)
    _assert_bit_exact(a, b)


@pytest.mark.parametrize("variant", ["skybyte-c", "skybyte-cp"])
def test_fused_predict_window_parity(variant, monkeypatch):
    """REPRO_FUSED_PREDICT=1 turns on staged boundary prediction (window
    sizing from pre-classified code-7 positions). Sizing is advisory, so
    the path must stay bit-exact — and must actually engage."""
    monkeypatch.setenv("REPRO_FUSED_PREDICT", "1")
    b = _run("batched", "bfs-dense", variant, n=12_000)
    assert _engine.FUSED_STATS["stage_rounds"] > 0, \
        "prediction path did not engage"
    monkeypatch.delenv("REPRO_FUSED_PREDICT")
    a = _run("reference", "bfs-dense", variant, n=12_000)
    _assert_bit_exact(a, b)


def test_fused_stats_accounting():
    """FUSED_STATS is reset per batched run and splits the cell's events
    between the fused kernel, the vector path, and the span fallback;
    fused_fraction stays a valid ratio."""
    out = _run("batched", "bfs-dense", "skybyte-c", n=12_000)
    s = _engine.FUSED_STATS
    assert s["fused_events"] > 0
    total = s["fused_events"] + s["vector_events"] + s["span_events"]
    assert 0 < total <= out["n"]
    assert 0.0 <= _engine.fused_fraction(out["n"]) <= 1.0
