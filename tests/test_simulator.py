"""Simulator behaviour + property-based invariants.

Property tests use hypothesis when installed (optional dev dependency:
``pip install hypothesis``) and fall back to the deterministic sampler in
_hypothesis_compat otherwise, so the suite collects and runs either way."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import SimConfig
from repro.core.simulator import simulate
from repro.core.traces import WORKLOADS, gen_thread_trace

N = 40_000  # small but past warm-up


def test_deterministic():
    a = simulate("bc", "skybyte-full", total_req=N, seed=3)
    b = simulate("bc", "skybyte-full", total_req=N, seed=3)
    assert a["exec_ns"] == b["exec_ns"]
    assert a["flash_write_pages"] == b["flash_write_pages"]


def test_variant_ordering():
    """DRAM-only is fastest; SkyByte-Full beats Base-CSSD; AMAT improves."""
    base = simulate("srad", "base-cssd", total_req=N)
    full = simulate("srad", "skybyte-full", total_req=N)
    dram = simulate("srad", "dram-only", total_req=N)
    assert dram["exec_ns"] < full["exec_ns"] < base["exec_ns"]
    assert full["amat_ns"] < base["amat_ns"]


def test_request_conservation():
    """Every generated request is retired exactly once."""
    r = simulate("tpcc", "skybyte-full", total_req=N)
    assert r["n"] == r["n_req_per_thread"] * r["n_threads"]
    classes = (r["host_r"] + r["host_w"] + r["hit_log"] + r["hit_cache"]
               + r["miss_flash"] + r["ssd_w"])
    assert classes == r["n"]


def test_ctx_switch_only_with_flag():
    r = simulate("bc", "skybyte-wp", total_req=N)
    assert r["ctx_switches"] == 0
    r = simulate("bc", "skybyte-c", total_req=N)
    assert r["ctx_switches"] > 0


def test_dram_only_flat_latency():
    r = simulate("ycsb", "dram-only", total_req=N)
    assert r["miss_flash"] == 0 and r["flash_write_pages"] == 0
    assert abs(r["amat_ns"] - 70.0) < 1.0


@settings(max_examples=8, deadline=None)
@given(
    wl=st.sampled_from(sorted(WORKLOADS)),
    seed=st.integers(0, 5),
)
def test_trace_statistics(wl, seed):
    """Generated traces respect Table I parameters."""
    spec = WORKLOADS[wl]
    tr = gen_thread_trace(spec, 20_000, seed, scale=128)
    wr = float(np.mean(tr["write"]))
    assert abs(wr - spec.write_ratio) < 0.08, (wl, wr, spec.write_ratio)
    assert tr["page"].min() >= 0
    assert tr["page"].max() < tr["n_pages"]
    assert (tr["line"] >= 0).all() and (tr["line"] < 64).all()
    # Fig 6 shape: dirty lines per page are few
    import collections

    per_page = collections.defaultdict(set)
    for p, l, w in zip(tr["page"][:5000], tr["line"][:5000], tr["write"][:5000]):
        if w:
            per_page[int(p)].add(int(l))
    if per_page:
        mean_dirty = np.mean([len(v) for v in per_page.values()])
        assert mean_dirty <= 8.0


@settings(max_examples=6, deadline=None)
@given(
    threshold=st.sampled_from([500.0, 2000.0, 8000.0]),
    policy=st.sampled_from(["RR", "RANDOM", "CFS"]),
)
def test_policies_and_thresholds_complete(threshold, policy):
    """Any trigger threshold / scheduling policy still retires all work
    (no lost wakeups, no deadlock) and keeps latency accounting sane."""
    cfg = dataclasses.replace(
        SimConfig(), ctx_threshold_ns=threshold, sched_policy=policy
    )
    r = simulate("dlrm", "skybyte-full", cfg=cfg, total_req=20_000)
    assert r["n"] == r["n_req_per_thread"] * r["n_threads"]
    assert r["exec_ns"] > 0
    assert r["amat_ns"] >= 0


@settings(max_examples=6, deadline=None)
@given(log_mb=st.sampled_from([16, 64, 256]))
def test_write_log_capacity_monotonic(log_mb):
    """A larger write log never increases compaction count."""
    cfg_small = dataclasses.replace(SimConfig(), write_log_bytes=16 << 20)
    cfg_big = dataclasses.replace(SimConfig(), write_log_bytes=log_mb << 20)
    small = simulate("srad", "skybyte-w", cfg=cfg_small, total_req=N)
    big = simulate("srad", "skybyte-w", cfg=cfg_big, total_req=N)
    assert big["compactions"] <= small["compactions"]


def test_trace_cache_eviction_logs_summary(tmp_path, monkeypatch, caplog):
    """REPRO_TRACE_CACHE_GB pruning used to be silent; the LRU eviction
    pass must log a one-line count/bytes summary and actually shrink the
    directory, never touching the just-written artifact."""
    import logging
    import os

    from repro.core import traces as tr

    monkeypatch.setattr(tr, "_TRACE_DIR", tmp_path)
    # three 1 MiB artifacts against a ~2 MiB cap -> one eviction
    paths = []
    for i in range(3):
        p = tmp_path / f"fake_{i}.npz"
        p.write_bytes(b"\0" * (1 << 20))
        os.utime(p, (1_000_000 + i, 1_000_000 + i))  # distinct LRU order
        paths.append(p)
    monkeypatch.setenv("REPRO_TRACE_CACHE_GB", str(2.5 / 1024))
    with caplog.at_level(logging.INFO, logger="repro.core.traces"):
        evicted = tr._evict_lru(keep=paths[0])
    assert evicted == 1
    assert not paths[1].exists()  # oldest non-kept artifact went first
    assert paths[0].exists() and paths[2].exists()
    assert any("evicted 1 artifact" in r.message for r in caplog.records)


def test_trace_cache_eviction_silent_when_under_cap(tmp_path, monkeypatch,
                                                    caplog):
    """No pruning -> no log line (the summary must not spam every store)."""
    import logging

    from repro.core import traces as tr

    monkeypatch.setattr(tr, "_TRACE_DIR", tmp_path)
    p = tmp_path / "fake.npz"
    p.write_bytes(b"\0" * 1024)
    monkeypatch.setenv("REPRO_TRACE_CACHE_GB", "1")
    with caplog.at_level(logging.INFO, logger="repro.core.traces"):
        assert tr._evict_lru(keep=p) == 0
    assert p.exists()
    assert not caplog.records


def test_trace_cache_corrupt_artifact_evicted_and_regenerated(
        tmp_path, monkeypatch, caplog):
    """A truncated npz (grid worker killed mid-write on a non-atomic
    filesystem) must be detected on load, unlinked with a one-line
    warning, and transparently regenerated — bit-identical, since trace
    generation is seeded. It must not be re-parsed-and-re-failed on
    every later run."""
    import logging

    from repro.core import traces as tr

    monkeypatch.setattr(tr, "_TRACE_DIR", tmp_path)
    monkeypatch.setenv("REPRO_TRACE_CACHE", "1")
    tr.gen_traces.cache_clear()
    good = tr.gen_traces("tpcc", 2, 500, seed=0, scale=64)
    path = tmp_path / f"tpcc_2t_500r_0s_64x_{tr._source_fingerprint()}.npz"
    assert path.exists()
    raw = path.read_bytes()
    path.write_bytes(raw[: len(raw) // 2])  # mid-write truncation
    tr.gen_traces.cache_clear()  # force the disk path, not the lru hit
    with caplog.at_level(logging.WARNING, logger="repro.core.traces"):
        regen = tr.gen_traces("tpcc", 2, 500, seed=0, scale=64)
    assert any("corrupt artifact" in r.message for r in caplog.records)
    assert path.exists(), "regeneration must re-store the artifact"
    reloaded = tr._load_traces(path, 2)  # and the new file must parse
    for a, b, c in zip(good, regen, reloaded):
        assert a["n_pages"] == b["n_pages"] == c["n_pages"]
        assert (a["page"] == b["page"]).all()
        assert (b["page"] == c["page"]).all()
    tr.gen_traces.cache_clear()
