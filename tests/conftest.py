"""Shared test fixtures."""
import os

import pytest


@pytest.fixture(autouse=True, scope="session")
def _no_engine_override():
    """A lingering REPRO_SIM_ENGINE (exported by benchmarks.run --engine
    sessions) overrides the cfg.engine the parity tests set explicitly,
    silently turning every reference-vs-batched comparison into a
    self-comparison. Strip it for the whole test session — unless
    REPRO_SIM_ENGINE_PIN=1 says the override is deliberate (scripts/ci.sh
    `ref` stage: the behavioural simulator subset forced onto the
    reference engine; never combine the pin with the parity suites)."""
    if os.environ.get("REPRO_SIM_ENGINE_PIN") == "1":
        yield
        return
    old = os.environ.pop("REPRO_SIM_ENGINE", None)
    yield
    if old is not None:
        os.environ["REPRO_SIM_ENGINE"] = old
