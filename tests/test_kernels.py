"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.kv_log_append.ops import kv_log_append
from repro.kernels.kv_log_append.ref import kv_log_append_ref
from repro.kernels.log_compact.ops import log_compact
from repro.kernels.log_compact.ref import log_compact_ref
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,hd,page,P,N",
    [
        (2, 4, 2, 32, 8, 8, 3),
        (3, 8, 4, 64, 16, 16, 4),
        (1, 6, 2, 16, 4, 6, 5),  # GQA group 3
        (4, 4, 4, 128, 8, 12, 2),  # MHA
    ],
)
def test_paged_attention_sweep(B, H, KV, hd, page, P, N, dtype):
    rng = np.random.default_rng(B * 100 + H)
    q = _rand(rng, (B, H, hd), dtype)
    kp = _rand(rng, (P, page, KV, hd), dtype)
    vp = _rand(rng, (P, page, KV, hd), dtype)
    pt = jnp.asarray(
        rng.choice(P, size=B * N, replace=B * N > P).reshape(B, N), jnp.int32
    )
    pt = pt.at[0, N - 1].set(-1)  # one non-resident page
    lengths = jnp.asarray(rng.integers(1, N * page + 1, size=B), jnp.int32)
    ref = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    out = paged_decode_attention(q, kp, vp, pt, lengths, use_pallas=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(out, np.float32), atol=tol, rtol=tol
    )


def test_paged_attention_with_log_merge():
    rng = np.random.default_rng(7)
    B, H, KV, hd, page, P, N, S = 3, 8, 4, 64, 16, 16, 4, 8
    q = _rand(rng, (B, H, hd), jnp.float32)
    kp = _rand(rng, (P, page, KV, hd), jnp.float32)
    vp = _rand(rng, (P, page, KV, hd), jnp.float32)
    pt = jnp.asarray(rng.choice(P, size=B * N, replace=False).reshape(B, N), jnp.int32)
    log_k = _rand(rng, (S, KV, hd), jnp.float32)
    log_v = _rand(rng, (S, KV, hd), jnp.float32)
    meta = -jnp.ones((S, 2), jnp.int32)
    meta = meta.at[0].set(jnp.array([1, 60])).at[1].set(jnp.array([1, 61]))
    # pages valid < 48 (compaction watermark), log covers the rest
    page_lengths = jnp.asarray([48, 48, 48], jnp.int32)
    lengths = jnp.asarray([48, 62, 48], jnp.int32)
    ref = paged_decode_attention_ref(
        q, kp, vp, pt, lengths, log_k, log_v, meta, page_lengths=page_lengths
    )
    out = paged_decode_attention(
        q, kp, vp, pt, lengths, log_k, log_v, meta, page_lengths=page_lengths,
        use_pallas=True,
    )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (2, 64, 4, 2, 32, 16, 16),
    (1, 128, 8, 8, 64, 32, 64),
    (2, 96, 6, 2, 16, 32, 32),
])
def test_flash_attention_sweep(B, S, H, KV, hd, bq, bk, causal, dtype):
    rng = np.random.default_rng(S + H)
    q = _rand(rng, (B, S, H, hd), dtype)
    k = _rand(rng, (B, S, KV, hd), dtype)
    v = _rand(rng, (B, S, KV, hd), dtype)
    ref = flash_attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(out, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("L,S,B,KV,hd,tail", [
    (2, 32, 4, 2, 16, 0), (3, 64, 8, 4, 32, 17), (1, 16, 2, 1, 8, 14),
])
def test_kv_log_append_sweep(L, S, B, KV, hd, tail):
    rng = np.random.default_rng(L * S)
    log_k = _rand(rng, (L, S, KV, hd), jnp.float32)
    log_v = _rand(rng, (L, S, KV, hd), jnp.float32)
    meta = -jnp.ones((S, 2), jnp.int32)
    kn = _rand(rng, (L, B, KV, hd), jnp.float32)
    vn = _rand(rng, (L, B, KV, hd), jnp.float32)
    req = jnp.asarray(rng.integers(0, 8, B), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 100, B), jnp.int32)
    r = kv_log_append_ref(log_k, log_v, meta, jnp.int32(tail), kn, vn, req, pos)
    o = kv_log_append(log_k, log_v, meta, jnp.int32(tail), kn, vn, req, pos)
    for a, b in zip(r, o):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


@pytest.mark.parametrize("L,P,page,KV,hd,S,F", [
    (2, 6, 8, 2, 16, 32, 4), (1, 4, 16, 4, 32, 16, 2),
])
def test_log_compact_sweep(L, P, page, KV, hd, S, F):
    rng = np.random.default_rng(P * page)
    kp = _rand(rng, (L, P, page, KV, hd), jnp.float32)
    vp = _rand(rng, (L, P, page, KV, hd), jnp.float32)
    log_k = _rand(rng, (L, S, KV, hd), jnp.float32)
    log_v = _rand(rng, (L, S, KV, hd), jnp.float32)
    meta = -jnp.ones((S, 2), jnp.int32)
    # scatter a handful of log entries over (request, position)
    for i in range(S // 2):
        meta = meta.at[i].set(
            jnp.array([int(rng.integers(0, 3)), int(rng.integers(0, P * page))])
        )
    # engine invariant: flush targets reference distinct (request, logical)
    # pairs and distinct pool slots
    slots = rng.choice(P, size=F - 1, replace=False)
    pairs = rng.choice(3 * 3, size=F - 1, replace=False)
    ft_rows = [[int(pr // 3), int(pr % 3), int(s)] for pr, s in zip(pairs, slots)]
    ft_rows.append([-1, 0, 0])  # padding row
    ft = jnp.asarray(ft_rows, jnp.int32)
    rk, rv = log_compact_ref(kp, vp, log_k, log_v, meta, ft)
    ok, ov = log_compact(kp, vp, log_k, log_v, meta, ft)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(ok), atol=1e-6)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(ov), atol=1e-6)
