"""Integration tests for the SkyByte tiering runtime + serving engine.

The decisive test: the tiered engine's greedy decode must be TOKEN-IDENTICAL
to plain dense decode, under page-pool pressure (parking = coordinated
context switches, promotion/eviction = adaptive migration) and across log
compactions — i.e. the paper's mechanisms change performance, never
results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.tiering import TieredKVConfig
from repro.models.api import ModelSpec
from repro.serving.engine import Request, TieredEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("qwen3-1.7b")
    spec = ModelSpec(cfg)
    params = spec.init(jax.random.PRNGKey(0))
    return spec, params


def ref_decode(spec, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = spec.prefill(params, toks)
    out = [int(jnp.argmax(logits[0]))]
    S = len(prompt)
    maxlen = S + n_new + 4
    dc = spec.init_cache(1, maxlen)
    for kk in ("k", "v"):
        dc[kk] = jnp.pad(cache[kk], [(0, 0), (0, 0), (0, maxlen - S), (0, 0), (0, 0)])
    pos = jnp.int32(S)
    for _ in range(n_new - 1):
        logits, dc = spec.decode_step(
            params, dc, jnp.asarray([[out[-1]]], jnp.int32), pos
        )
        out.append(int(jnp.argmax(logits[0])))
        pos = pos + 1
    return out


def run_engine(spec, params, prompts, kv, n_new, use_pallas=False):
    eng = TieredEngine(spec, params, kv, use_pallas=use_pallas)
    for rid, p in prompts.items():
        eng.add_request(Request(rid=rid, prompt=p, max_new_tokens=n_new))
    stats = eng.run(max_steps=2000)
    return eng, stats


CASES = {
    "no_pressure": TieredKVConfig(page_size=8, n_hbm_pages=32, max_requests=4,
                                  max_pages_per_req=12, log_slots=256, batch=2,
                                  promote_pages_per_step=8),
    "compaction": TieredKVConfig(page_size=8, n_hbm_pages=32, max_requests=4,
                                 max_pages_per_req=12, log_slots=8, batch=2,
                                 promote_pages_per_step=8),
    "pool_pressure": TieredKVConfig(page_size=8, n_hbm_pages=16, max_requests=4,
                                    max_pages_per_req=12, log_slots=32, batch=2,
                                    promote_pages_per_step=2),
    "serial_batch1": TieredKVConfig(page_size=8, n_hbm_pages=9, max_requests=4,
                                    max_pages_per_req=12, log_slots=32, batch=1,
                                    promote_pages_per_step=8),
}


@pytest.mark.parametrize("case", list(CASES))
def test_engine_equals_dense_decode(model, case):
    spec, params = model
    kv = CASES[case]
    prompts = {0: list(range(7, 27)), 1: list(range(40, 75)),
               2: list(range(5, 18))}
    n_new = 20
    refs = {rid: ref_decode(spec, params, p, n_new) for rid, p in prompts.items()}
    eng, stats = run_engine(spec, params, prompts, kv, n_new)
    for rid in prompts:
        assert eng.requests[rid].out == refs[rid], (
            f"{case}: req {rid} diverged (parks={stats.parks}, "
            f"compactions={stats.compactions})"
        )
    if case == "pool_pressure":
        assert stats.parks > 0, "pressure case should trigger context switches"
        assert stats.promoted_pages > 0
    if case == "compaction":
        assert stats.compactions > 0


def test_engine_pallas_path(model):
    """Same equivalence through the Pallas kernels (interpret mode)."""
    spec, params = model
    kv = TieredKVConfig(page_size=8, n_hbm_pages=16, max_requests=2,
                        max_pages_per_req=8, log_slots=32, batch=2,
                        promote_pages_per_step=4)
    prompts = {0: list(range(3, 19)), 1: list(range(21, 40))}
    n_new = 10
    refs = {rid: ref_decode(spec, params, p, n_new) for rid, p in prompts.items()}
    eng, stats = run_engine(spec, params, prompts, kv, n_new, use_pallas=True)
    for rid in prompts:
        assert eng.requests[rid].out == refs[rid]


def test_coalescing_reduces_page_writes(model):
    """The paper's core write-path claim, restated for serving: with the
    write log, page-granular writes ~ tokens/page_size, not ~ tokens."""
    spec, params = model
    kv = TieredKVConfig(page_size=8, n_hbm_pages=32, max_requests=2,
                        max_pages_per_req=12, log_slots=16, batch=1,
                        promote_pages_per_step=8)
    prompts = {0: list(range(10, 34))}
    eng, stats = run_engine(spec, params, prompts, kv, n_new=32)
    assert stats.compactions >= 1
    # without a log, every decoded token would dirty (and flush) its page:
    # flushed pages must be well below decoded tokens
    assert stats.flushed_pages < stats.decoded_tokens
    assert stats.coalesce_ratio > 1.5
