"""Fast-math turbo engine: the two-tier contract.

core/turbo.run_turbo replaces the fused engine's four per-event IEEE
float chains with gap prefix-sums and count*constant folds. That buys
speed by reassociating float additions — so its contract splits in two:

  * EXACT — every discrete decision and structure must be bit-equal to
    the reference engine: scheduler order, per-class event counts, FTL
    l2p/p2l/wear, WAF, GC events, fault/QoS counters, and the final
    DeviceState discrete arrays (DeviceState.discrete_signature()).
  * APPROXIMATE — finish times, AMAT, latency percentiles may drift
    within SimConfig.turbo_rtol; the engine exports its own a-priori
    bound as turbo_drift_max / turbo_drift_mean and must refuse (raise)
    when the bound exceeds the configured tolerance.

Conflict classes (fault/QoS/obs-active configs, inline-only promotion
policies) must refuse the fast path entirely and run the bit-exact
fallback, reporting drift 0.0.
"""
import dataclasses

import pytest

from repro.configs.base import SimConfig, VARIANTS
from repro.core import engine as _engine
from repro.core import turbo as _turbo
from repro.core.simulator import (ENGINES, Machine, Thread,
                                  _reference_quantum, _run_scheduler,
                                  simulate)
from repro.core.traces import gen_traces

from tests._hypothesis_compat import given, settings, st

# Timing outputs: the APPROXIMATE tier. Everything else in the result
# dict is discrete (counts, WAF, GC events, ...) and must be bit-equal.
APPROX_KEYS = {
    "lat_sum", "lat_host", "lat_hit", "lat_miss", "amat_ns", "exec_ns",
    "throughput_rps", "ssd_bw_util", "busy_ns", "gc_pause_ns_total",
    "gc_pause_max_ns", "lat_p50_ns", "lat_p95_ns", "lat_p99_ns",
    "lat_read_p50_ns", "lat_read_p95_ns", "lat_read_p99_ns",
}
# turbo-only exports and the obs blob (obs configs are a conflict class
# with their own bit-exact assertion below)
SKIP_KEYS = {"turbo_drift_max", "turbo_drift_mean", "obs"}

RTOL = 1e-6  # asserted ceiling across the sweep; measured drift ~1e-12


def _run(engine, workload, variant, n, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_two_tier(a, b, rtol=RTOL):
    assert set(a) | SKIP_KEYS == set(b) | SKIP_KEYS, set(a) ^ set(b)
    for k in a:
        if k in SKIP_KEYS:
            continue
        if k in APPROX_KEYS:
            x, y = float(a[k]), float(b[k])
            ref = max(abs(x), abs(y), 1e-300)
            assert abs(x - y) / ref <= rtol, (k, a[k], b[k])
        else:
            assert a[k] == b[k], (k, a[k], b[k])


def _assert_bit_exact(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k] == b[k], (k, a[k], b[k])


def _final_state(engine, workload, variant, n, seed=0, **overrides):
    """Drive one engine exactly as simulate() does, but keep the machine
    so the test can read the final DeviceState."""
    cfg = dataclasses.replace(
        SimConfig(), engine=engine, **overrides).variant(variant)
    n_req = max(n // cfg.n_threads, 1)
    traces = gen_traces(workload, cfg.n_threads, n_req, seed=seed,
                        scale=cfg.scale)
    threads = [Thread(t, tr) for t, tr in enumerate(traces)]
    page_space = int(max(tr["n_pages"] for tr in traces))
    if engine == "reference":
        m = Machine(cfg, seed, page_space)
        _run_scheduler(m, cfg, threads, _reference_quantum)
    else:
        assert _engine.supported(cfg)
        _engine.reset_cache_stats()
        _engine.reset_fused_stats()
        m = _engine.BatchedMachine(cfg, seed, page_space)
        if engine == "turbo":
            _turbo.reset_turbo_stats()
            _turbo.run_turbo(m, cfg, threads)
        else:
            _engine.run_fused(m, cfg, threads)
    return m.state


# ---------------------------------------------------------------- exact tier

@pytest.mark.parametrize("variant", VARIANTS)
def test_turbo_discrete_state_bit_equal(variant):
    """The final DeviceState's discrete signature — tier membership and
    order, cache tags/stamps, log contents, FTL mapping/wear/frontiers,
    integer counters — is bit-equal across all three engines."""
    ref = _final_state("reference", "tpcc", variant, n=12_000)
    tur = _final_state("turbo", "tpcc", variant, n=12_000)
    assert ref.discrete_signature() == tur.discrete_signature()
    bat = _final_state("batched", "tpcc", variant, n=12_000)
    assert ref.discrete_signature() == bat.discrete_signature()


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["bfs-dense", "srad", "tpcc", "ycsb"]),
       st.sampled_from(VARIANTS),
       st.integers(min_value=0, max_value=3),
       st.sampled_from(["greedy", "cost-benefit"]))
def test_turbo_two_tier_property_sweep(workload, variant, seed, gc_policy):
    """Randomized cells: discrete outputs bit-equal to the reference,
    timing outputs within RTOL, drift bound honest and within rtol."""
    a = _run("reference", workload, variant, n=8_000, seed=seed,
             gc_policy=gc_policy)
    b = _run("turbo", workload, variant, n=8_000, seed=seed,
             gc_policy=gc_policy)
    _assert_two_tier(a, b)
    assert 0.0 <= b["turbo_drift_max"] <= SimConfig().turbo_rtol
    assert 0.0 <= b["turbo_drift_mean"] <= b["turbo_drift_max"]


# ------------------------------------------------------- conflict refusal

@pytest.mark.parametrize("overrides", [
    dict(fault=dataclasses.replace(
        SimConfig().fault, read_error_rate=3e-3, outage_rate=1e-4)),
    dict(gc_suspend=True, read_priority=True),
    dict(obs=dataclasses.replace(SimConfig().obs, enabled=True)),
    dict(promo_policy="tpp"),
], ids=["faults", "qos", "obs", "inline-promo"])
def test_turbo_conflict_refusal(overrides):
    """Conflict classes refuse the fast path: TURBO_STATS counts the
    fallback, the run is fully bit-exact (floats included), and the
    exported drift is exactly 0.0."""
    a = _run("batched", "tpcc", "skybyte-full", n=8_000, **overrides)
    b = _run("turbo", "tpcc", "skybyte-full", n=8_000, **overrides)
    assert _turbo.TURBO_STATS["fallbacks"] == 1
    assert _turbo.TURBO_STATS["turbo_events"] == 0
    assert b["turbo_drift_max"] == 0.0
    assert b["turbo_drift_mean"] == 0.0
    for k in a:
        assert a[k] == b[k], (k, a[k], b[k])


def test_turbo_fast_path_actually_engages():
    """The refusal test above proves nothing if plain cells also fall
    back: on a conflict-free cell the counter kernel must retire the
    overwhelming majority of events."""
    out = _run("turbo", "tpcc", "skybyte-full", n=12_000)
    s = _turbo.TURBO_STATS
    assert s["fallbacks"] == 0
    assert s["turbo_events"] > 0
    assert s["turbo_events"] >= out["n"] // 2
    assert s["flushes"] > 0


# ------------------------------------------------------------ drift bounds

def test_turbo_drift_bound_positive_and_bounded():
    """A nonempty turbo run must export a strictly positive a-priori
    bound (it did reassociate) that stays within the default rtol."""
    out = _run("turbo", "srad", "skybyte-cp", n=20_000)
    assert 0.0 < out["turbo_drift_max"] <= SimConfig().turbo_rtol
    assert 0.0 < out["turbo_drift_mean"] <= out["turbo_drift_max"]


def test_turbo_rtol_violation_raises():
    """turbo_rtol is a hard ceiling: a tolerance below the achievable
    bound must raise instead of silently shipping drifted numbers."""
    with pytest.raises(ValueError, match="turbo"):
        _run("turbo", "tpcc", "skybyte-full", n=8_000, turbo_rtol=1e-15)


def test_turbo_rtol_must_be_positive():
    with pytest.raises(ValueError, match="turbo_rtol"):
        dataclasses.replace(SimConfig(), turbo_rtol=0.0)


# ------------------------------------------------------------- default path

def test_zero_turbo_is_noop():
    """Default (non-turbo) configs never touch the turbo machinery: the
    stats stay zero and the result carries no drift exports above 0."""
    _turbo.reset_turbo_stats()
    out = _run("batched", "bfs-dense", "skybyte-c", n=8_000)
    assert all(v == 0 for v in _turbo.TURBO_STATS.values())
    assert out.get("turbo_drift_max", 0.0) == 0.0
    assert out.get("turbo_drift_mean", 0.0) == 0.0


def test_engine_registry_rejects_unknown():
    cfg = dataclasses.replace(SimConfig(), engine="warp")
    with pytest.raises(ValueError, match="valid engines"):
        simulate("tpcc", "base-cssd", cfg, total_req=1_000)
    assert "turbo" in ENGINES
