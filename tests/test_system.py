"""Per-arch smoke tests: every assigned architecture instantiates at a
reduced config and runs forward/loss/grad (+ prefill/decode for one arch
per family) on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models.api import ModelSpec

FAMILY_REPS = ("qwen3-1.7b", "olmoe-1b-7b", "whisper-base", "rwkv6-3b",
               "zamba2-7b", "llava-next-34b")


def _finite(x):
    return bool(jnp.all(jnp.isfinite(jnp.asarray(x, jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grad(arch):
    cfg = get_reduced(arch)
    spec = ModelSpec(cfg)
    rng = jax.random.PRNGKey(0)
    params = spec.init(rng)
    batch = spec.smoke_batch(rng, batch=2, seq=32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: spec.loss(p, batch), has_aux=True
    )(params)
    assert _finite(loss), f"{arch}: loss not finite"
    gnorm = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert _finite(gnorm), f"{arch}: grads not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_prefill_decode(arch):
    cfg = get_reduced(arch)
    spec = ModelSpec(cfg)
    rng = jax.random.PRNGKey(1)
    params = spec.init(rng)
    batch = spec.smoke_batch(rng, batch=2, seq=32)
    logits, cache = spec.prefill(params, batch["tokens"], batch.get("frontend"))
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)
    dec_cache = spec.init_cache(2, 48)
    for k, v in cache.items():
        if k in dec_cache and k != "length":
            if dec_cache[k].shape == v.shape:
                dec_cache[k] = v
            else:
                pads = [(0, a - b) for a, b in zip(dec_cache[k].shape, v.shape)]
                dec_cache[k] = jnp.pad(v, pads)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = spec.decode_step(params, dec_cache, tok, jnp.int32(32))
    assert logits2.shape == (2, cfg.vocab)
    assert _finite(logits2), f"{arch}: decode produced non-finite logits"
    assert int(cache2["length"]) == 33


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_schema_consistency(arch):
    """Schema-derived shapes match initialized parameters exactly."""
    cfg = get_reduced(arch)
    spec = ModelSpec(cfg)
    abstract = spec.abstract_params()
    params = spec.init(jax.random.PRNGKey(0))
    ab = jax.tree_util.tree_leaves(abstract)
    cc = jax.tree_util.tree_leaves(params)
    assert len(ab) == len(cc)
    for a, c in zip(ab, cc):
        assert a.shape == c.shape and a.dtype == c.dtype
