"""Optimizer, gradient compression, data pipeline, checkpointing, sharding
rules, HLO analyzer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import OptimConfig, get_reduced
from repro.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import DEFAULT_RULES, param_specs, spec_for_leaf
from repro.launch import hlo_analysis
from repro.models.api import ModelSpec
from repro.models.common import Leaf
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.grad_compress import compress_decompress, error_feedback_update


def test_adamw_converges_quadratic():
    cfg = OptimConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    lr = jnp.float32(0.1)
    for _ in range(200):
        grads = {"w": 2 * state.master["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(cfg, state, grads, lr)
    assert float(jnp.sum(jnp.abs(state.master["w"]))) < 1e-2


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=64))
def test_compress_error_bounded(vals):
    g = jnp.asarray(vals, jnp.float32)
    g_hat, err = compress_decompress(g)
    scale = max(float(jnp.max(jnp.abs(g))), 1e-12) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(g_hat + err), np.asarray(g), atol=1e-5)


def test_error_feedback_accumulates():
    """Residual carries quantization error to the next step (no loss)."""
    g = {"w": jnp.full((8,), 0.001, jnp.float32)}
    res = {"w": jnp.zeros((8,), jnp.float32)}
    total = jnp.zeros((8,), jnp.float32)
    for _ in range(50):
        g_hat, res = error_feedback_update(g, res)
        total = total + g_hat["w"]
    # sum of compressed grads ~ sum of true grads (error feedback property)
    np.testing.assert_allclose(np.asarray(total), 0.001 * 50, rtol=0.1)


def test_data_pipeline_restart_safe():
    a = SyntheticLM(1000, 64, 4, seed=7)
    b = SyntheticLM(1000, 64, 4, seed=7)
    for step in (0, 3, 11):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"], a.batch_at(1)["tokens"])


def test_checkpoint_roundtrip_and_elastic(tmp_path):
    spec = ModelSpec(get_reduced("smollm-135m"))
    params = spec.init(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, params, extra={"data_step": 6})
    target = spec.init(jax.random.PRNGKey(1))  # different values
    restored, extra, step = ck.restore(target)
    assert step == 5 and extra["data_step"] == 6
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # elastic: restore with explicit (single-device) shardings
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    restored2, _, _ = ck.restore(target, shardings=sharding)
    assert all(
        x.sharding == sharding for x in jax.tree_util.tree_leaves(restored2)
    )


def test_checkpoint_keep_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones((3,)) * s})
    assert sorted(ck.all_steps()) == [3, 4]
    restored, _, step = ck.restore({"x": jnp.zeros((3,))})
    assert step == 4 and float(restored["x"][0]) == 4.0


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_sharding_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible: sharded
    leaf = Leaf((256, 1024), ("embed", "ffn"))
    spec = spec_for_leaf(leaf, mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # 40 heads do not divide 16 -> replicated on that dim
    leaf = Leaf((40, 64), ("heads", None))
    spec = spec_for_leaf(leaf, mesh)
    assert spec == jax.sharding.PartitionSpec(None, None)


HLO_SAMPLE = """
%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %iter = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %next = s32[] add(%iter, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%next, %ar)
}

%cond (p.1: (s32[], f32[8,128])) -> pred[] {
  %p.1 = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p.1), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main.1 (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %a)
  %wh = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_hlo_analysis_loop_multiplication():
    r = hlo_analysis.analyze(HLO_SAMPLE)
    assert r["entry"] == "main.1"
    # dot flops = 2*8*128*128 per iteration, 7 iterations
    assert r["flops"] == 7 * 2 * 8 * 128 * 128
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 7
    # ring all-reduce traffic: 2 * bytes * (n-1)/n, n=4, bytes=8*128*4
    expected = 7 * 2.0 * (8 * 128 * 4) * (3 / 4)
    assert abs(ar["traffic"] - expected) < 1e-6
