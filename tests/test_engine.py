"""Batched replay engine exactness + determinism (core/engine.py).

The contract: for the same seed, engine="batched" produces the same stats
as engine="reference" — integer counters exactly, float accumulators and
exec_ns within float tolerance (in practice they are bit-equal: the fast
path replays the reference's sequential addition order)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SimConfig, VARIANTS
from repro.core.simulator import simulate

N = 6_000  # small but enough to exercise misses, promotions, compactions
WORKLOADS = ("bfs-dense", "srad", "tpcc")


def _run(engine, workload, variant, n=N, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_same(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, (float, np.floating)) or isinstance(y, (float, np.floating)):
            assert float(x) == pytest.approx(float(y), rel=1e-12, abs=1e-9), \
                (k, x, y)
        else:  # ints, strings, None
            assert x == y, (k, x, y)


@pytest.mark.parametrize("workload", WORKLOADS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_engine_parity(workload, variant):
    """Batched == reference across the full paper ablation grid."""
    _assert_same(_run("reference", workload, variant),
                 _run("batched", workload, variant))


def test_engine_parity_compaction_heavy():
    """A small write log forces many compaction cycles through the fast
    path's log-fill boundary prediction."""
    over = dict(write_log_bytes=16 << 20)
    _assert_same(_run("reference", "srad", "skybyte-w", **over),
                 _run("batched", "srad", "skybyte-w", **over))


def test_engine_parity_demotion_pressure():
    """A tiny host DRAM budget exercises promotion + demotion churn."""
    over = dict(host_dram_bytes=64 << 20)
    _assert_same(_run("reference", "dlrm", "skybyte-full", **over),
                 _run("batched", "dlrm", "skybyte-full", **over))


def test_engine_parity_gc_pressure():
    """GC-triggering flash misses: a tiny flash array makes the FTL's
    free-page accounting cross the GC threshold repeatedly, so the
    transcribed miss/eviction paths must drive erase + migration windows
    (channel timeline perturbations) identically in both engines."""
    over = dict(flash_bytes=2 << 30, ssd_dram_bytes=32 << 20, cache_ways=1,
                write_log_bytes=1 << 20)
    for variant in ("base-cssd", "skybyte-w"):
        a = _run("reference", "radix", variant, n=16_000, **over)
        b = _run("batched", "radix", variant, n=16_000, **over)
        assert a["gc_events"] > 0, "corner must actually trigger GC"
        _assert_same(a, b)


def test_engine_parity_back_to_back_log_fills():
    """Back-to-back write-log fills: a log of a few dozen entries makes the
    fill -> compaction-drain boundary fire every handful of writes, so the
    engine's fill prediction + transcribed drain run constantly."""
    over = dict(write_log_bytes=1 << 19)
    a = _run("reference", "tpcc", "skybyte-w", n=10_000, **over)
    b = _run("batched", "tpcc", "skybyte-w", n=10_000, **over)
    assert a["compactions"] > 20, "corner must force frequent compactions"
    _assert_same(a, b)


def test_engine_parity_demotion_under_host_pressure():
    """Demotion under host-tier pressure: promotion threshold 1 with a
    host tier of a few dozen pages turns every promotion into a
    promote+demote pair (with the demoted page's dirty writeback), all on
    the transcribed promotion boundary path."""
    over = dict(host_dram_bytes=16 << 20, promote_threshold=1)
    for variant in ("skybyte-p", "skybyte-full"):
        a = _run("reference", "dlrm", variant, n=10_000, **over)
        b = _run("batched", "dlrm", variant, n=10_000, **over)
        assert a["demotions"] > 100, "corner must churn the host tier"
        _assert_same(a, b)


@pytest.mark.parametrize("policy", ["RR", "RANDOM"])
def test_engine_parity_sched_policies(policy):
    """Scheduling policy decisions (incl. the RANDOM rng stream) are shared
    by both engines."""
    over = dict(sched_policy=policy)
    _assert_same(_run("reference", "bc", "skybyte-full", **over),
                 _run("batched", "bc", "skybyte-full", **over))


def test_engine_seed_determinism():
    """Same seed -> identical output dict; different seed -> different."""
    a = _run("batched", "bc", "skybyte-full", seed=3)
    b = _run("batched", "bc", "skybyte-full", seed=3)
    c = _run("batched", "bc", "skybyte-full", seed=4)
    _assert_same(a, b)
    assert a["exec_ns"] == b["exec_ns"]
    assert a["exec_ns"] != c["exec_ns"]


def test_engine_fallback_policies():
    """tpp/astriflash promotion consume RNG per access; the batched engine
    must fall back to the reference loop and still match it exactly."""
    for policy in ("tpp", "astriflash"):
        over = dict(promo_policy=policy)
        _assert_same(_run("reference", "srad", "skybyte-cp", **over),
                     _run("batched", "srad", "skybyte-cp", **over))


def test_batched_never_calls_serve(monkeypatch):
    """Machine.serve() is the reference loop's parity oracle ONLY: the
    batched engine transcribes every boundary event (misses, GC, log
    fills, promotions, Base-CSSD write misses) into its own paths."""
    from repro.core import engine as eng

    def boom(*a, **k):
        raise AssertionError("batched engine called Machine.serve()")

    monkeypatch.setattr(eng.BatchedMachine, "serve", boom, raising=False)
    cells = [("bfs-dense", "skybyte-c", {}), ("srad", "skybyte-w", {}),
             ("tpcc", "base-cssd", {}), ("dlrm", "skybyte-full", {}),
             ("bc", "skybyte-cp", dict(promo_policy="tpp"))]
    for workload, variant, over in cells:
        _run("batched", workload, variant, n=4_000, **over)


def test_engine_unknown_rejected():
    with pytest.raises(ValueError):
        _run("warp-drive", "srad", "base-cssd")
