"""Batched replay engine exactness + determinism (core/engine.py).

The contract: for the same seed, engine="batched" produces the same stats
as engine="reference" — integer counters exactly, float accumulators and
exec_ns within float tolerance (in practice they are bit-equal: the fast
path replays the reference's sequential addition order)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SimConfig, VARIANTS
from repro.core.simulator import simulate

N = 6_000  # small but enough to exercise misses, promotions, compactions
WORKLOADS = ("bfs-dense", "srad", "tpcc")


def _run(engine, workload, variant, n=N, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_same(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, (float, np.floating)) or isinstance(y, (float, np.floating)):
            assert float(x) == pytest.approx(float(y), rel=1e-12, abs=1e-9), \
                (k, x, y)
        else:  # ints, strings, None
            assert x == y, (k, x, y)


@pytest.mark.parametrize("workload", WORKLOADS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_engine_parity(workload, variant):
    """Batched == reference across the full paper ablation grid."""
    _assert_same(_run("reference", workload, variant),
                 _run("batched", workload, variant))


def test_engine_parity_compaction_heavy():
    """A small write log forces many compaction cycles through the fast
    path's log-fill boundary prediction."""
    over = dict(write_log_bytes=16 << 20)
    _assert_same(_run("reference", "srad", "skybyte-w", **over),
                 _run("batched", "srad", "skybyte-w", **over))


def test_engine_parity_demotion_pressure():
    """A tiny host DRAM budget exercises promotion + demotion churn."""
    over = dict(host_dram_bytes=64 << 20)
    _assert_same(_run("reference", "dlrm", "skybyte-full", **over),
                 _run("batched", "dlrm", "skybyte-full", **over))


@pytest.mark.parametrize("policy", ["RR", "RANDOM"])
def test_engine_parity_sched_policies(policy):
    """Scheduling policy decisions (incl. the RANDOM rng stream) are shared
    by both engines."""
    over = dict(sched_policy=policy)
    _assert_same(_run("reference", "bc", "skybyte-full", **over),
                 _run("batched", "bc", "skybyte-full", **over))


def test_engine_seed_determinism():
    """Same seed -> identical output dict; different seed -> different."""
    a = _run("batched", "bc", "skybyte-full", seed=3)
    b = _run("batched", "bc", "skybyte-full", seed=3)
    c = _run("batched", "bc", "skybyte-full", seed=4)
    _assert_same(a, b)
    assert a["exec_ns"] == b["exec_ns"]
    assert a["exec_ns"] != c["exec_ns"]


def test_engine_fallback_policies():
    """tpp/astriflash promotion consume RNG per access; the batched engine
    must fall back to the reference loop and still match it exactly."""
    for policy in ("tpp", "astriflash"):
        over = dict(promo_policy=policy)
        _assert_same(_run("reference", "srad", "skybyte-cp", **over),
                     _run("batched", "srad", "skybyte-cp", **over))


def test_engine_unknown_rejected():
    with pytest.raises(ValueError):
        _run("warp-drive", "srad", "base-cssd")
