"""Cross-quantum classification cache: invalidation correctness.

The cache (core/engine.py) keeps per-thread class codes alive across
scheduling quanta and repairs them through per-page epochs. These tests
attack exactly the invalidation machinery: configurations tuned so that
device state churns as fast as possible — a tiny write log (compactions
every few hundred writes flood-invalidate every logged line), a one-way
data cache a fraction of the working set (every miss evicts), an
aggressive promotion threshold with a tiny host DRAM (promotion/demotion
ping-pong) — and assert the batched engine still reproduces the reference
loop stat-for-stat across all 8 paper variants.

Property test via tests/_hypothesis_compat.py: runs under real hypothesis
when installed, under the deterministic fallback sampler otherwise.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SimConfig, VARIANTS
from repro.core import engine
from repro.core.simulator import simulate
from tests._hypothesis_compat import given, settings, st

N = 4_000

# Maximum-churn overrides: log fills after ~128 lines, the data cache is
# direct-mapped and tiny, promotion triggers on the second access into a
# host DRAM of a few dozen pages (constant demotion), and the cached-range
# window is small enough that range exhaustion also gets exercised.
CHURN = dict(
    write_log_bytes=1 << 20,       # ~128 log entries per buffer at scale
    ssd_dram_bytes=24 << 20,       # a handful of cache pages
    cache_ways=1,                  # 1-entry sets: every miss evicts
    host_dram_bytes=16 << 20,      # tiny host tier: demotion ping-pong
    promote_threshold=2,           # aggressive promotion
    cls_cache_window=512,
)


def _run(engine_name, workload, variant, n=N, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine_name, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_same(a, b, ctx=""):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, (float, np.floating)) or isinstance(y, (float, np.floating)):
            assert float(x) == pytest.approx(float(y), rel=1e-12, abs=1e-9), \
                (ctx, k, x, y)
        else:
            assert x == y, (ctx, k, x, y)


@pytest.mark.parametrize("variant", VARIANTS)
def test_parity_under_forced_churn(variant):
    """Batched == reference for every paper variant with every churn
    mechanism (compaction floods, eviction storms, promotion ping-pong)
    firing orders of magnitude more often than in the paper configs."""
    _assert_same(_run("reference", "srad", variant, **CHURN),
                 _run("batched", "srad", variant, **CHURN),
                 ctx=variant)


@settings(max_examples=12)
@given(
    workload=st.sampled_from(["bfs-dense", "bc", "srad", "tpcc", "dlrm"]),
    variant=st.sampled_from(list(VARIANTS)),
    seed=st.integers(0, 5),
    log_mb=st.integers(1, 4),
    cache_mb=st.integers(16, 64),
    host_mb=st.integers(8, 64),
    thr=st.integers(1, 4),
    window=st.sampled_from([128, 1024, 65536]),
    min_run=st.sampled_from([0.0, 20.0, 1e9]),
)
def test_parity_property(workload, variant, seed, log_mb, cache_mb,
                         host_mb, thr, window, min_run):
    """Random points in (workload, variant, churn-knob) space; min_run 0
    pins the engine to the cached vector path, 1e9 to the inline span, so
    both consumers see every churn combination."""
    over = dict(
        write_log_bytes=log_mb << 20,
        ssd_dram_bytes=cache_mb << 20,
        host_dram_bytes=host_mb << 20,
        promote_threshold=thr,
        cls_cache_window=window,
        cls_cache_min_run=min_run,
        cache_ways=1,
    )
    _assert_same(
        _run("reference", workload, variant, n=2_500, seed=seed, **over),
        _run("batched", workload, variant, n=2_500, seed=seed, **over),
        ctx=(workload, variant, seed, log_mb, cache_mb, host_mb, thr,
             window, min_run),
    )


def test_cache_disabled_matches_reference():
    """cls_cache=False falls back to per-chunk classification and must be
    just as exact."""
    for variant in ("skybyte-c", "skybyte-full"):
        _assert_same(
            _run("reference", "bfs-dense", variant, **CHURN),
            _run("batched", "bfs-dense", variant, cls_cache=False, **CHURN),
            ctx=("cache-off", variant),
        )


def test_cache_engaged_and_observable():
    """The ctx-switch-bound cell actually exercises the cache (validations
    happen, hits occur) and the observability counters stay coherent."""
    engine.reset_cache_stats()
    _run("batched", "bfs-dense", "skybyte-full", n=40_000,
         cls_cache_min_run=0.0)
    cs = engine.CACHE_STATS
    assert cs["builds"] > 0, "cache never built"
    assert cs["checks"] > 0, "cache never validated on re-entry"
    assert cs["clean"] + cs["repairs"] <= cs["checks"]
    assert cs["classified"] > 0
    assert 0.0 <= engine.cache_hit_rate() <= 1.0
    assert 0.0 <= engine.cache_repair_rate() <= 1.0


def test_epoch_monotonicity_and_bumps():
    """Membership mutations bump page epochs; epochs never decrease.

    Since the unified-state refactor the epoch board lives on the shared
    DeviceState, so the plain Machine (reference engine) maintains it too —
    the same assertions hold for both machine types."""
    from repro.core.simulator import Machine

    cfg = SimConfig().variant("skybyte-full")
    for m in (engine.BatchedMachine(cfg, seed=0, page_space=64),
              Machine(cfg, seed=0, page_space=64)):
        ds = m.state
        assert ds.epoch_clock == 0
        m.cache.insert(3, True)
        e1 = int(ds.page_epoch[3])
        assert e1 > 0
        m.cache.remove(3)
        assert int(ds.page_epoch[3]) > e1
        m.host[5] = True
        assert int(ds.page_epoch[5]) > 0
        # log appends must NOT bump (absorbed by the log overlay instead)
        clock = ds.epoch_clock
        m.log.append(7, 1)
        assert ds.epoch_clock == clock
        assert int(ds.log_bits[7]) == 1 << 1  # bitmask mirrors the append
        # compaction floods: every page the drained buffer held is bumped
        m.log.swap_for_compaction()
        assert int(ds.page_epoch[7]) > 0
        assert int(ds.log_bits[7]) == 0


def test_shared_state_single_copy():
    """Tentpole invariant: both engines' machines expose ONE DeviceState;
    the policy views (cache/log/host) mutate the same arrays the batched
    classifier gathers — no shadow mirrors anywhere."""
    cfg = SimConfig().variant("skybyte-full")
    m = engine.BatchedMachine(cfg, seed=0, page_space=64)
    ds = m.state
    assert m.cache.s is ds and m.log.s is ds and m.channels.s is ds
    assert m.host is ds.host and m.acc_count is ds.acc
    m.cache.insert(9, False)
    assert bool(ds.cache_res[9])
    m.host[11] = True
    assert bool(ds.host.arr[11])
    m.cache.remove(9)
    assert not bool(ds.cache_res[9])
    # engine.py no longer defines any shadow-mirror subclasses
    for name in ("_ShadowHost", "_ShadowCache", "_ShadowLog"):
        assert not hasattr(engine, name)
