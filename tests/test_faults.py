"""Device fault model + crash-consistent recovery (core/faults.py).

Three contracts under test:

* **Engine parity with faults on.** Fault-affected cells are a conflict
  class — the batched engine falls back to the scheduler path and the
  scalar span calls the shared ``Channels.read`` — so both engines must
  consume the identical counter-hashed fault stream and stay bit-exact,
  including every ``ft_*`` counter, with retries, outages, power losses
  and die failures all firing.
* **Crash consistency.** Power loss drops the volatile page cache and
  in-flight programs, but every cacheline-log page survives: the replay
  is idempotent (a second crash replays the same set and leaves the
  l2p/p2l mapping consistent), the log dicts themselves are untouched,
  and the FTL invariants hold after recovery.
* **Graceful degradation.** Spare-pool exhaustion (cascading die
  failures) must flip the device into read-only degraded mode and count
  host-visible write errors — never raise.
"""
import dataclasses

import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import FaultConfig, SimConfig, VARIANTS
from repro.core.device_state import DeviceState
from repro.core.faults import _SALT_OUTAGE, _SALT_RETRY, _u01
from repro.core.flash import BlockFtl, check_invariants
from repro.core.simulator import Machine, simulate
from repro.core.ssd import Channels
from repro.core.traces import WORKLOADS, gen_thread_trace

# Same collision-forcing overrides as the fused-engine suite: a one-way
# cache + tiny DRAM tier keeps flash-read traffic high enough that every
# scheduled fault ordinal is actually reached within a few thousand
# requests.
CONFLICT_OVER = dict(
    cache_ways=1, ssd_dram_bytes=32 << 20, flash_bytes=2 << 30,
    write_log_bytes=1 << 20, host_dram_bytes=64 << 20,
)

# every fault class armed at once
ALL_FAULTS = FaultConfig(read_error_rate=3e-3, outage_rate=1e-3,
                         power_loss_at=(500,), die_fail_at=(900,))


def _run(engine, workload, variant, n, seed=0, fault=ALL_FAULTS,
         **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, fault=fault,
                              **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_bit_exact(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k] == b[k], (k, a[k], b[k])


# ---------------------------------------------------------------------------
# deterministic fault stream
# ---------------------------------------------------------------------------

def test_u01_deterministic_bounded_and_salted():
    for idx in (0, 1, 17, 10**9):
        for salt in (_SALT_RETRY, _SALT_OUTAGE):
            u = _u01(42, idx, salt)
            assert 0.0 <= u < 1.0
            assert u == _u01(42, idx, salt)  # pure function of the args
    # the two salts must decorrelate the streams (same seed/ordinal)
    assert _u01(0, 7, _SALT_RETRY) != _u01(0, 7, _SALT_OUTAGE)
    # and the seed must matter
    assert _u01(0, 7, _SALT_RETRY) != _u01(1, 7, _SALT_RETRY)


# ---------------------------------------------------------------------------
# engine parity with every fault class firing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_parity_under_faults_all_variants(variant):
    a = _run("reference", "tpcc", variant, n=8_000, **CONFLICT_OVER)
    b = _run("batched", "tpcc", variant, n=8_000, **CONFLICT_OVER)
    _assert_bit_exact(a, b)


def test_fault_stream_actually_engages():
    """The parity sweep above proves nothing if no fault ever fires."""
    out = _run("batched", "tpcc", "skybyte-full", n=8_000, **CONFLICT_OVER)
    assert out["retry_reads"] > 0
    assert out["power_loss_events"] == 1
    assert out["die_failures"] == 1
    assert out["recovery_ns_max"] >= ALL_FAULTS.recovery_scan_ns


@settings(max_examples=6, deadline=None)
@given(
    wl=st.sampled_from(["tpcc", "srad", "bfs-dense"]),
    variant=st.sampled_from(["base-cssd", "skybyte-c", "skybyte-full"]),
    seed=st.integers(0, 2),
    crash=st.sampled_from([200, 800]),
)
def test_power_loss_parity_and_recovery_tail(wl, variant, seed, crash):
    """Property sweep: a mid-run power loss at any read ordinal leaves
    the engines bit-identical, and the recovery barrier (replay drain +
    firmware scan) shows up in the stats."""
    fc = FaultConfig(power_loss_at=(crash,))
    a = _run("reference", wl, variant, 6_000, seed=seed, fault=fc,
             **CONFLICT_OVER)
    b = _run("batched", wl, variant, 6_000, seed=seed, fault=fc,
             **CONFLICT_OVER)
    _assert_bit_exact(a, b)
    assert a["power_loss_events"] == 1
    assert a["recovery_ns_max"] >= fc.recovery_scan_ns


# ---------------------------------------------------------------------------
# read-retry ladder: latency ordering
# ---------------------------------------------------------------------------

def test_retry_ladder_latency_ordering():
    """A higher first-sense error rate engages a superset of read
    ordinals (u < rate) and walks each engaged read at least as far down
    the ladder, so retry traffic and the read tail are monotone in the
    rate — and a zero rate must match the no-fault-model baseline
    exactly except for the fault counters themselves."""
    outs = []
    for rate in (0.0, 1e-3, 1e-2, 5e-2):
        fc = FaultConfig(read_error_rate=rate, power_loss_at=(10**9,))
        outs.append(_run("batched", "bfs-dense", "base-cssd", 8_000,
                         fault=fc, **CONFLICT_OVER))
    for lo, hi in zip(outs, outs[1:]):
        assert hi["retry_reads"] >= lo["retry_reads"]
        assert hi["retry_steps"] >= hi["retry_reads"]
        assert hi["lat_p99_ns"] >= lo["lat_p99_ns"]
        assert hi["lat_sum"] >= lo["lat_sum"]
    assert outs[-1]["retry_reads"] > 0, "top rate must engage the ladder"
    baseline = _run("batched", "bfs-dense", "base-cssd", 8_000,
                    fault=FaultConfig(), **CONFLICT_OVER)
    zero = outs[0]
    for k in baseline:
        assert zero[k] == baseline[k], (k, zero[k], baseline[k])


# ---------------------------------------------------------------------------
# crash consistency: durable log replay
# ---------------------------------------------------------------------------

def _served_machine(wl="srad", variant="skybyte-full", n=4_000, seed=0):
    """A Machine driven through n requests with the fault model attached
    but no fault scheduled to fire on its own."""
    cfg = dataclasses.replace(SimConfig().variant(variant),
                              fault=FaultConfig(power_loss_at=(10**9,)))
    tr = gen_thread_trace(WORKLOADS[wl], n, seed, scale=128)
    m = Machine(cfg, seed=seed, page_space=int(tr["n_pages"]))
    wslots = []
    now = 0.0
    for p, l, w in zip(tr["page"].tolist(), tr["line"].tolist(),
                       tr["write"].tolist()):
        now += 50.0
        lat, blocked, _ = m.serve(int(p), int(l), bool(w), now, wslots)
        now += lat if blocked is None else 0.0
    return m, now


@settings(max_examples=4, deadline=None)
@given(wl=st.sampled_from(["srad", "tpcc"]), seed=st.integers(0, 2))
def test_power_loss_replay_idempotent_and_log_durable(wl, seed):
    """Crash the device twice in a row. The durable log dicts must be
    byte-identical across both recoveries (the log is persistent media —
    replay never consumes it), the second replay must re-program exactly
    the same page set, every logged page must stay mapped, and the FTL
    invariants must hold after each recovery."""
    m, now = _served_machine(wl=wl, seed=seed)
    s = m.state
    fs = s.flash
    assert s.log_active or s.log_old, "corner needs a non-empty log"
    log_before = (dict(s.log_old), dict(s.log_active))
    logged = set(s.log_old) | set(s.log_active)

    m.fault._power_loss(now)
    r1 = s.ft_replayed_pages
    assert r1 == len(logged)
    assert (dict(s.log_old), dict(s.log_active)) == log_before
    check_invariants(fs)
    # volatile cache fully dropped
    assert not s.cache_res.any()
    for p in logged:
        pp = int(fs.l2p[p])
        assert pp >= 0 and bool(fs.pvalid[pp]) and int(fs.p2l[pp]) == p

    m.fault._power_loss(now + 1.0)  # immediate second crash
    assert s.ft_replayed_pages == 2 * r1, "replay must be idempotent"
    assert (dict(s.log_old), dict(s.log_active)) == log_before
    assert s.ft_power_losses == 2
    check_invariants(fs)
    for p in logged:
        pp = int(fs.l2p[p])
        assert pp >= 0 and bool(fs.pvalid[pp]) and int(fs.p2l[pp]) == p


def test_power_loss_without_log_loses_dirty_cache():
    """The baseline CSSD has no cacheline log: a crash must drop dirty
    cache lines as counted data loss and replay nothing — the cost the
    SkyByte write log exists to avoid."""
    m, now = _served_machine(wl="srad", variant="base-cssd")
    s = m.state
    assert s.cache_dirty.any(), "corner needs dirty cache lines at crash"
    m.fault._power_loss(now)
    assert s.ft_lost_dirty_pages > 0
    assert s.ft_replayed_pages == 0
    assert not s.cache_res.any()
    check_invariants(s.flash)


# ---------------------------------------------------------------------------
# die failure + graceful degradation
# ---------------------------------------------------------------------------

def test_die_failure_remaps_and_keeps_parity():
    fc = FaultConfig(die_fail_at=(300,))
    a = _run("reference", "tpcc", "base-cssd", 8_000, fault=fc,
             **CONFLICT_OVER)
    b = _run("batched", "tpcc", "base-cssd", 8_000, fault=fc,
             **CONFLICT_OVER)
    _assert_bit_exact(a, b)
    assert a["die_failures"] == 1
    assert a["bad_blocks"] >= 1
    assert a["degraded_mode"] == 0  # one die must not exhaust the spares


def test_die_fail_requires_block_backend():
    cfg = dataclasses.replace(SimConfig(), ftl_backend="legacy",
                              fault=FaultConfig(die_fail_at=(1,)))
    with pytest.raises(ValueError, match="block FTL backend"):
        simulate("tpcc", "base-cssd", cfg, total_req=100)


def test_spare_exhaustion_degrades_readonly_not_raises():
    """Unit-level: mark the whole free pool bad (what cascading die
    failures do) and ask for a fresh block. The empty pool must flip the
    device into degraded mode — the old behaviour was an uncaught
    RuntimeError from the middle of the service path — and every program
    after that must be swallowed as a counted write error, never raise.
    (GC reclamation keeping up with rewrites is the healthy path and is
    covered by the end-to-end cascade test below.)"""
    cfg = dataclasses.replace(SimConfig(), pages_per_block=4, op_ratio=0.0)
    ds = DeviceState(cfg, 8)
    ftl = BlockFtl(cfg, ds, Channels(cfg, ds))
    fs = ds.flash
    for b in fs.free:  # the pool dies, state stays consistent (bad)
        fs.blk_state_mv[b] = 3
    fs.free.clear()
    assert ftl._pop_free() == -1, "empty pool must yield the sentinel"
    assert ds.ft_degraded == 1
    now = 0.0
    for step in range(64):  # must never raise
        now += 100.0
        ftl.on_flash_write(now, step % 8)
    assert ds.ft_write_errors == 64
    check_invariants(fs, degraded=True)


def test_cascading_die_failures_degrade_end_to_end():
    """Full-stack: starvation-level over-provisioning plus a drumbeat of
    die failures must exhaust the spare pool mid-run; the device finishes
    the workload degraded (write errors counted in Stats) instead of
    blowing up, and both engines agree bit-exactly on the whole ordeal."""
    fc = FaultConfig(die_fail_at=tuple(range(100, 4100, 100)))
    over = dict(CONFLICT_OVER, op_ratio=0.015)
    a = _run("reference", "tpcc", "base-cssd", 20_000, fault=fc, **over)
    b = _run("batched", "tpcc", "base-cssd", 20_000, fault=fc, **over)
    _assert_bit_exact(a, b)
    assert a["degraded_mode"] == 1
    assert a["degraded_writes"] > 0
    assert a["die_failures"] > 1
