"""Die-level QoS (core/qos.py): engine parity across the full knob grid,
suspend/resume accounting bounds, read-priority tail monotonicity, and
superblock striped-frontier placement.

The QoS contract is the fault-model contract (DESIGN.md "Die-level
QoS"): QoS-active reads are a conflict class served by ONE shared
arbitration function (QosModel.read) that both engines dispatch to, so
bit-exactness is structural — these tests drive it through the regimes
where the mechanisms actually engage (GC storms at starvation
over-provisioning, striped frontiers that put every die in a victim's
blast radius) and assert the full Stats dict stays identical."""
import dataclasses
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import FaultConfig, SimConfig
from repro.core.device_state import DIES_PER_CHANNEL
from repro.core.engine import BatchedMachine, batched_quantum
from repro.core.flash import blk_loc, check_invariants
from repro.core.simulator import Machine, Thread, _reference_quantum, simulate
from repro.core.traces import WORKLOADS, gen_thread_trace

# Starvation-level over-provisioning + tiny log + small host tier: GC
# runs near-continuously, so suspend windows and program backlogs are
# dense enough for every mechanism to engage within ~50k requests.
STORM = dict(op_ratio=0.015, write_log_bytes=1 << 19,
             host_dram_bytes=64 << 20)
# The QoS grid: every (gc_suspend, read_priority, superblock) corner.
QOS_GRID = tuple(itertools.product((False, True), repeat=3))


def _run(engine, workload, variant, n, seed=0, **overrides):
    cfg = dataclasses.replace(SimConfig(), engine=engine, **overrides)
    return simulate(workload, variant, cfg, total_req=n, seed=seed)


def _assert_bit_exact(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k] == b[k], (k, a[k], b[k])


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------

def test_superblock_requires_block_backend():
    with pytest.raises(ValueError, match="superblock"):
        dataclasses.replace(SimConfig(), superblock=True,
                            ftl_backend="legacy")


def test_negative_suspend_knobs_rejected():
    with pytest.raises(ValueError, match="gc_suspend_max"):
        dataclasses.replace(SimConfig(), gc_suspend_max=-1)
    with pytest.raises(ValueError, match="gc_suspend_ns"):
        dataclasses.replace(SimConfig(), gc_suspend_ns=-1.0)
    with pytest.raises(ValueError, match="gc_resume_ns"):
        dataclasses.replace(SimConfig(), gc_resume_ns=-1.0)


def test_zero_read_priority_cap_rejected():
    with pytest.raises(ValueError, match="read_priority_wait_ns"):
        dataclasses.replace(SimConfig(), read_priority_wait_ns=0.0)


def test_faults_and_qos_are_mutually_exclusive():
    fault = FaultConfig(read_error_rate=1e-3)
    for knob in ("gc_suspend", "read_priority", "superblock"):
        with pytest.raises(ValueError, match="fault"):
            dataclasses.replace(SimConfig(), fault=fault, **{knob: True})


def test_zero_qos_attaches_nothing():
    """Default config must not pay for QoS: no QosModel on Channels (the
    fast path's only cost is one ``is not None``), and superblock alone —
    placement, not arbitration — must also leave it detached so the fused
    engine keeps running striped configs."""
    assert not SimConfig().qos_enabled
    m = Machine(SimConfig().variant("base-cssd"), 0, 1 << 14)
    assert m.channels.qos is None and m.qos is None
    sb = dataclasses.replace(SimConfig().variant("base-cssd"),
                             superblock=True)
    assert not sb.qos_enabled
    assert Machine(sb, 0, 1 << 14).channels.qos is None
    on = dataclasses.replace(SimConfig().variant("base-cssd"),
                             gc_suspend=True)
    m = Machine(on, 0, 1 << 14)
    assert m.channels.qos is m.qos is not None


# ---------------------------------------------------------------------------
# engine parity across the knob grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("susp,rp,sb", QOS_GRID)
def test_parity_qos_grid(susp, rp, sb):
    """Every knob corner, both engines, full-Stats bit-equality through a
    GC storm. dlrm under the striped frontier is the densest regime:
    every victim's blast radius covers all dies, so suspends, die
    bypasses and bus jumps all fire."""
    over = dict(STORM, gc_suspend=susp, read_priority=rp, superblock=sb)
    a = _run("reference", "dlrm", "base-cssd", n=48_000, **over)
    b = _run("batched", "dlrm", "base-cssd", n=48_000, **over)
    assert a["gc_events"] > 0, "corner must trigger GC"
    if susp and sb:
        assert a["gc_suspends"] > 0, "storm corner must exercise suspend"
    if rp:
        assert a["rp_bypasses"] > 0, "storm corner must exercise bypass"
    _assert_bit_exact(a, b)


@pytest.mark.parametrize("variant", ["skybyte-w", "skybyte-full"])
def test_parity_superblock_fused_path(variant):
    """Superblock WITHOUT suspend/read-priority is placement-only and
    must keep the fused mega-loop eligible — parity here covers the six
    inlined ``l2p[p] // loc_div`` routing sites against the oracle."""
    over = dict(STORM, superblock=True)
    a = _run("reference", "srad", variant, n=48_000, **over)
    b = _run("batched", "srad", variant, n=48_000, **over)
    assert a["gc_events"] > 0
    _assert_bit_exact(a, b)


# ---------------------------------------------------------------------------
# property sweep: mappings + invariants under striped frontiers
# ---------------------------------------------------------------------------

def _drive(machine_cls, runner, cfg, tr, seed=0):
    th = Thread(0, tr)
    m = machine_cls(cfg, seed, int(tr["n_pages"]))
    wslots = []
    t = 0.0
    while th.i < th.n:
        if t < th.ready:
            t = th.ready
        t = runner(m, cfg, th, t, wslots)
    return m


@settings(max_examples=6, deadline=None)
@given(
    wl=st.sampled_from(["dlrm", "srad", "radix"]),
    op=st.sampled_from([0.015, 0.03]),
    policy=st.sampled_from(["greedy", "cost-benefit"]),
    sb=st.sampled_from([False, True]),
    seed=st.integers(0, 3),
)
def test_qos_mapping_property_sweep(wl, op, policy, sb, seed):
    """After GC churn with the full QoS stack on, the l2p/p2l mapping and
    wear history must agree bit-for-bit between the engines and satisfy
    check_invariants — striping must not corrupt the seal/migrate/erase
    lifecycle."""
    cfg = dataclasses.replace(
        SimConfig().variant("skybyte-full"), op_ratio=op, gc_policy=policy,
        superblock=sb, gc_suspend=True, read_priority=True,
        write_log_bytes=1 << 19, host_dram_bytes=64 << 20)
    tr = gen_thread_trace(WORKLOADS[wl], 12_000, seed, scale=128)
    ma = _drive(Machine, _reference_quantum, cfg, tr, seed)
    mb = _drive(BatchedMachine, batched_quantum, cfg, tr, seed)
    fa, fb = ma.state.flash, mb.state.flash
    check_invariants(fa)
    check_invariants(fb)
    assert ma.state.gc_events == mb.state.gc_events
    assert ma.state.gc_suspends == mb.state.gc_suspends
    assert (fa.l2p == fb.l2p).all(), "engines disagree on page placement"
    assert (fa.p2l == fb.p2l).all()
    assert (fa.blk_erase == fb.blk_erase).all(), "wear histories diverged"


# ---------------------------------------------------------------------------
# suspend/resume accounting
# ---------------------------------------------------------------------------

def test_suspend_count_bounded_by_budget():
    """gc_suspends can never exceed gc_suspend_max per carved window, and
    a zero cap disables suspension entirely even with gc_suspend=True."""
    over = dict(STORM, superblock=True, gc_suspend=True)
    r = _run("batched", "dlrm", "base-cssd", n=48_000, **over)
    assert r["gc_suspends"] > 0
    assert r["gc_suspends"] == r["gc_resumes"]
    assert r["gc_suspends"] <= SimConfig().gc_suspend_max * r["gc_windows"]
    r0 = _run("batched", "dlrm", "base-cssd", n=48_000, gc_suspend_max=0,
              **over)
    assert r0["gc_suspends"] == 0
    assert r0["gc_pause_avoided_ns"] == 0.0


def test_suspend_collapses_gc_pause_without_waf_cost():
    """The mechanism's point: host-observed GC pause collapses (the
    dodged pause lands in gc_pause_avoided_ns instead) while the
    migration work itself — and therefore WAF — is untouched (suspension
    defers cleaning, it never skips it)."""
    over = dict(STORM, superblock=True)
    off = _run("batched", "dlrm", "base-cssd", n=48_000, **over)
    on = _run("batched", "dlrm", "base-cssd", n=48_000, gc_suspend=True,
              **over)
    assert on["gc_suspends"] > 0
    assert on["gc_pause_ns_total"] < 0.2 * off["gc_pause_ns_total"]
    assert on["gc_pause_avoided_ns"] > 0.0
    assert on["waf"] <= off["waf"] * 1.05, "suspension must not cost WAF"
    # per-suspension invariant: the read still pays exactly suspend_ns,
    # booked through the standard pause counters
    assert on["gc_pause_max_ns"] >= SimConfig().gc_suspend_ns


def test_read_priority_tail_monotonic():
    """On the GC-storm cell the read-only p99 with the full QoS stack on
    must not exceed the stack-off tail (and on this deterministic cell it
    is at least 2x better — the acceptance cell of the fig_gc_tail qos
    sweep at --quick scale)."""
    over = dict(STORM, superblock=True)
    off = _run("batched", "dlrm", "base-cssd", n=48_000, **over)
    on = _run("batched", "dlrm", "base-cssd", n=48_000, gc_suspend=True,
              read_priority=True, **over)
    assert on["rp_bypasses"] > 0
    assert on["rp_wait_saved_ns"] > 0.0
    assert on["lat_read_p99_ns"] <= off["lat_read_p99_ns"]
    assert on["lat_read_p99_ns"] * 2 <= off["lat_read_p99_ns"]
    assert on["waf"] <= off["waf"] * 1.05


def test_read_percentiles_ordered_and_within_mixed_population():
    """lat_read_p* are computed over a subset of the mixed population:
    they must be internally ordered, and the read p50 can never sit below
    the fastest constant class (host DRAM)."""
    r = _run("batched", "dlrm", "base-cssd", n=48_000, superblock=True,
             gc_suspend=True, read_priority=True, **STORM)
    assert (r["lat_read_p50_ns"] <= r["lat_read_p95_ns"]
            <= r["lat_read_p99_ns"])
    assert r["lat_read_p50_ns"] > 0.0
    assert (r["lat_p50_ns"] <= r["lat_p95_ns"] <= r["lat_p99_ns"])


# ---------------------------------------------------------------------------
# superblock striped placement
# ---------------------------------------------------------------------------

def test_superblock_phys_loc_stripes_pages_across_dies():
    """Per-die blocks map every page of a block to ONE (channel, die);
    the striped frontier spreads consecutive slots of the same block
    round-robin across channels first, dies second."""
    cfg = SimConfig().variant("skybyte-full")
    ftl = Machine(cfg, 0, 1 << 14).ftl
    ftl_sb = Machine(dataclasses.replace(cfg, superblock=True),
                     0, 1 << 14).ftl
    ppb, n_ch = ftl.fs.ppb, cfg.n_channels
    assert ftl.loc_div == ppb and ftl_sb.loc_div == 1
    # adopt a synthetic mapping: logical page i on physical page i
    for f in (ftl, ftl_sb):
        f.fs.l2p[:ppb] = np.arange(ppb)
    per_die = {ftl.phys_loc(p) for p in range(ppb)}
    assert len(per_die) == 1, "per-die block must live on one die"
    striped = [ftl_sb.phys_loc(p) for p in range(ppb)]
    assert striped[0] != striped[1], "adjacent slots must change die"
    # channel advances fastest, wrapping into the die index
    for p in range(min(ppb, 2 * n_ch) - 1):
        ch0, d0 = striped[p]
        ch1, d1 = striped[p + 1]
        assert ch1 == (ch0 + 1) % n_ch
        assert d1 == d0 + (1 if ch1 == 0 else 0)
    assert len(set(striped)) == min(ppb, n_ch * DIES_PER_CHANNEL)


def test_superblock_matches_blk_loc_contract():
    """phys_loc under striping must equal blk_loc applied to the raw
    physical page (loc_div=1), i.e. the same channel/die hash every
    engine-inlined routing site uses."""
    cfg = dataclasses.replace(SimConfig().variant("skybyte-full"),
                              superblock=True)
    ftl = Machine(cfg, 0, 1 << 14).ftl
    for pp in (0, 1, 7, 129, 1234):
        ftl.fs.l2p[0] = pp
        assert ftl.phys_loc(0) == blk_loc(pp, cfg.n_channels)


def test_superblock_waf_unchanged_gc_pause_denser():
    """Striping is placement-only: the victim-selection stream and
    migration volume (WAF) are driven by the same occupancy state, while
    the GC blast radius grows from one die to all of them — so the
    host-visible pause mass must grow while WAF stays put."""
    off = _run("batched", "dlrm", "base-cssd", n=48_000, **STORM)
    on = _run("batched", "dlrm", "base-cssd", n=48_000, superblock=True,
              **STORM)
    assert on["waf"] == pytest.approx(off["waf"], rel=0.05)
    assert on["gc_pause_ns_total"] > off["gc_pause_ns_total"]
    assert on["gc_stall_events"] > off["gc_stall_events"]
